//! Loopback TCP integration tests for the rust-native serving stack.
//! These run in the **default feature set** (no XLA): the paper's
//! Figure-5 serving story end to end — create → step × k → stats → close
//! over line-delimited JSON, with Aaren `state_bytes` constant in stream
//! length and the tf KV session surviving past the largest cache bucket.
//! The fold-kernel backends (mingru / minlstm / avg_attn) ride the same
//! wire: each is exercised against a local scalar control session,
//! bitwise, through steps / snapshot / restore / TTL spill.

use aaren::serve::server::{Client, ServeConfig, Server};
use aaren::serve::{wire_error, TF_BUCKETS};
use aaren::util::json::Json;

type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn base_cfg(channels: usize, shards: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards,
        ..ServeConfig::default()
    }
}

fn start_cfg(cfg: &ServeConfig) -> (std::net::SocketAddr, ServerHandle) {
    let server = Server::bind(cfg).expect("bind loopback");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

fn start_with_ttl(
    channels: usize,
    shards: usize,
    session_ttl: Option<std::time::Duration>,
) -> (std::net::SocketAddr, ServerHandle) {
    let mut cfg = base_cfg(channels, shards);
    cfg.session_ttl = session_ttl;
    start_cfg(&cfg)
}

fn start(channels: usize, shards: usize) -> (std::net::SocketAddr, ServerHandle) {
    start_with_ttl(channels, shards, None)
}

/// Unique scratch dir for spill-tier tests (std has no tempdir crate).
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "aaren-tcp-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn step_line(id: usize, x: &[f32]) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}]}}"#, xs.join(","))
}

#[test]
fn aaren_session_streams_with_constant_state() {
    let (addr, server) = start(4, 2);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let mut bytes = Vec::new();
    for t in 0..64 {
        let r = client.call(&step_line(id, &[0.1, 0.2, -0.3, 0.4])).unwrap();
        assert_eq!(r.usize_field("t").unwrap(), t + 1);
        assert_eq!(r.get("y").and_then(Json::as_arr).unwrap().len(), 4);
        bytes.push(r.usize_field("state_bytes").unwrap());
    }
    assert!(bytes.windows(2).all(|w| w[0] == w[1]), "aaren state must be constant: {bytes:?}");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn tf_session_state_grows_and_survives_past_largest_bucket() {
    let (addr, server) = start(1, 1);
    let mut client = Client::connect(&addr).unwrap();
    let id = client.call(r#"{"op":"create","kind":"tf"}"#).unwrap().usize_field("id").unwrap();
    let largest = TF_BUCKETS[TF_BUCKETS.len() - 1];
    let mut first_bytes = 0;
    let mut last_bytes = 0;
    for t in 0..largest + 40 {
        let r = client.call(&step_line(id, &[1.0])).unwrap();
        last_bytes = r.usize_field("state_bytes").unwrap();
        if t == 0 {
            first_bytes = last_bytes;
        }
        assert_eq!(r.usize_field("t").unwrap(), t + 1);
    }
    // the stream crossed every bucket and kept going past the largest one
    assert!(last_bytes > first_bytes, "kv cache must grow: {first_bytes} -> {last_bytes}");
    assert_eq!(last_bytes, 2 * (2 * largest) * 4, "one geometric doubling past the ladder");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn stats_aggregate_across_shards_and_close_frees_sessions() {
    let (addr, server) = start(4, 3);
    let mut client = Client::connect(&addr).unwrap();
    let mut ids = Vec::new();
    for kind in ["aaren", "tf", "aaren", "tf"] {
        let id = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        ids.push(id);
    }
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 4);
    // two aaren ((2 + channels) f32s each) + two tf (first bucket each)
    let aaren_bytes = (2 + 4) * 4;
    let tf_bytes = 2 * TF_BUCKETS[0] * 4 * 4;
    let total = stats.usize_field("total_state_bytes").unwrap();
    assert_eq!(total, 2 * aaren_bytes + 2 * tf_bytes);
    for id in &ids[..2] {
        client.call(&format!(r#"{{"op":"close","id":{id}}}"#)).unwrap();
    }
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 2);
    // a second connection reaches the same sessions
    let mut other = Client::connect(&addr).unwrap();
    let r = other.call(&step_line(ids[3], &[0.0, 0.0, 0.0, 0.0])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 1);
    other.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

fn steps_line(id: usize, tokens: &[&[f32]]) -> String {
    let rows: Vec<String> = tokens
        .iter()
        .map(|x| {
            let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!(r#"{{"op":"steps","id":{id},"xs":[{}]}}"#, rows.join(","))
}

#[test]
fn steps_block_matches_individual_step_calls() {
    // satellite property: a `steps` block over TCP is indistinguishable
    // from the same tokens sent as N individual `step` calls — outputs,
    // t and state_bytes all line up, for both session kinds.
    let (addr, server) = start(3, 2);
    let mut client = Client::connect(&addr).unwrap();
    let tokens: Vec<Vec<f32>> = (0..12)
        .map(|i| vec![0.25 * i as f32 - 1.0, (i % 3) as f32, -0.5 * i as f32])
        .collect();
    for kind in ["aaren", "tf"] {
        let one = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        let block = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        let mut want = Vec::new();
        let mut want_bytes = 0;
        for x in &tokens {
            let r = client.call(&step_line(one, x)).unwrap();
            let y: Vec<f64> = r
                .get("y")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            want.push(y);
            want_bytes = r.usize_field("state_bytes").unwrap();
        }
        let refs: Vec<&[f32]> = tokens.iter().map(|x| x.as_slice()).collect();
        let r = client.call(&steps_line(block, &refs)).unwrap();
        let got: Vec<Vec<f64>> = r
            .get("ys")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
            .collect();
        assert_eq!(got, want, "kind {kind}: batched outputs diverge from per-step outputs");
        assert_eq!(r.usize_field("t").unwrap(), tokens.len(), "kind {kind}");
        assert_eq!(r.usize_field("state_bytes").unwrap(), want_bytes, "kind {kind}");
    }
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn steps_errors_are_replies_and_empty_blocks_are_noops() {
    let (addr, server) = start(2, 1);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    // wrong width: error reply, session unharmed
    let r = client.call_raw(&steps_line(id, &[&[1.0, 2.0][..], &[3.0][..]])).unwrap();
    assert!(r.get("error").is_some(), "ragged rows must be rejected");
    let r = client.call_raw(&steps_line(id, &[&[1.0][..], &[2.0][..]])).unwrap();
    assert!(r.get("error").is_some(), "width-1 rows on a 2-channel session must be rejected");
    // an empty block is a no-op that still gets a well-formed reply
    let r = client.call(&steps_line(id, &[])).unwrap();
    assert_eq!(r.get("ys").and_then(Json::as_arr).unwrap().len(), 0);
    assert_eq!(r.usize_field("t").unwrap(), 0);
    // the session still works afterwards
    let r = client.call(&step_line(id, &[0.5, -0.5])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 1);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn idle_sessions_are_evicted_after_the_ttl() {
    // ROADMAP PR-2 follow-up: a client that disconnects without `close`
    // must not leak its sessions forever once a TTL is configured.
    let ttl = std::time::Duration::from_millis(500);
    let (addr, server) = start_with_ttl(2, 2, Some(ttl));
    {
        let mut doomed = Client::connect(&addr).unwrap();
        doomed.call(r#"{"op":"create","kind":"aaren"}"#).unwrap();
        doomed.call(r#"{"op":"create","kind":"tf"}"#).unwrap();
        let stats = doomed.call(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(stats.usize_field("sessions").unwrap(), 2);
        // client drops without close
    }
    std::thread::sleep(ttl + std::time::Duration::from_millis(600));
    let mut client = Client::connect(&addr).unwrap();
    // the stats fan-out drains every shard, triggering the sweep; the
    // first reply may still count pre-sweep sessions, so read twice
    client.call(r#"{"op":"stats"}"#).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 0, "idle sessions must be swept");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

/// Exactly-representable tokens: every value is a small dyadic rational,
/// so JSON f64 → f32 → printed f64 round-trips are lossless and output
/// comparisons can demand BIT equality, not closeness.
fn dyadic_token(i: usize, channels: usize) -> Vec<f32> {
    (0..channels).map(|c| ((i * 7 + c * 3) % 13) as f32 * 0.25 - 1.5).collect()
}

fn ys_as_f64(reply: &Json) -> Vec<Vec<f64>> {
    reply
        .get("ys")
        .and_then(Json::as_arr)
        .expect("ys")
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
        .collect()
}

fn as_f64(v: &[f32]) -> Vec<f64> {
    v.iter().map(|x| *x as f64).collect()
}

/// Drive a local reference session through the same tokens the server
/// saw and return the expected outputs (exact, as f64 rows). `kind` is
/// any fold-kernel wire name or `"tf"`.
fn control_outputs(kind: &str, channels: usize, tokens: &[Vec<f32>]) -> Vec<Vec<f64>> {
    use aaren::scan::KernelKind;
    use aaren::serve::{NativeScanSession, NativeTfSession, StreamSession};
    let mut session: Box<dyn StreamSession> = match kind {
        "tf" => Box::new(NativeTfSession::new(channels)),
        _ => Box::new(NativeScanSession::new_kernel(
            KernelKind::from_wire(kind).expect("wire kernel name"),
            channels,
        )),
    };
    tokens.iter().map(|x| as_f64(&session.step(x).unwrap())).collect()
}

#[test]
fn snapshot_restore_roundtrip_is_bitwise_on_one_server() {
    // snapshot a live stream, restore it as a second session on the same
    // server, then feed both the same tail: every output must be
    // bit-identical, and t must continue from the snapshot point
    let channels = 4;
    let (addr, server) = start(channels, 2);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let warm: Vec<Vec<f32>> = (0..9).map(|i| dyadic_token(i, channels)).collect();
    for x in &warm {
        client.call(&step_line(id, x)).unwrap();
    }
    let snap = client.call(&format!(r#"{{"op":"snapshot","id":{id}}}"#)).unwrap();
    assert_eq!(snap.str_field("kind").unwrap(), "aaren");
    assert_eq!(snap.usize_field("t").unwrap(), warm.len());
    assert_eq!(snap.usize_field("channels").unwrap(), channels);
    let blob = snap.str_field("state").unwrap().to_string();

    let restored = client
        .call(&format!(r#"{{"op":"restore","state":"{blob}"}}"#))
        .unwrap();
    let twin = restored.usize_field("id").unwrap();
    assert_ne!(twin, id, "restore must create a NEW session");
    assert_eq!(restored.usize_field("t").unwrap(), warm.len());
    assert_eq!(restored.str_field("kind").unwrap(), "aaren");

    for (i, x) in (0..7).map(|i| (i, dyadic_token(100 + i, channels))) {
        let a = client.call(&step_line(id, &x)).unwrap();
        let b = client.call(&step_line(twin, &x)).unwrap();
        assert_eq!(
            a.get("y").unwrap().to_string(),
            b.get("y").unwrap().to_string(),
            "tail step {i}: restored twin diverged"
        );
        assert_eq!(a.usize_field("t").unwrap(), b.usize_field("t").unwrap());
        assert_eq!(
            a.usize_field("state_bytes").unwrap(),
            b.usize_field("state_bytes").unwrap()
        );
    }
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn ttl_spill_then_touch_resumes_bitwise() {
    // the tentpole acceptance: a session spilled to disk by the TTL sweep
    // and then touched again must resume with outputs bitwise identical
    // to a never-evicted twin fed the same token stream (the local
    // control session), for EVERY native kind — each fold kernel plus tf
    let channels = 3;
    let ttl = std::time::Duration::from_millis(300);
    let spill = scratch_dir("spill-touch");
    let mut cfg = base_cfg(channels, 2);
    cfg.session_ttl = Some(ttl);
    cfg.spill_dir = Some(spill.clone());
    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();

    let head: Vec<Vec<f32>> = (0..11).map(|i| dyadic_token(i, channels)).collect();
    let tail: Vec<Vec<f32>> = (0..8).map(|i| dyadic_token(50 + i, channels)).collect();
    let kinds = ["aaren", "mingru", "minlstm", "avg_attn", "tf"];
    let mut ids = Vec::new();
    for kind in kinds {
        let id = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
        client.call(&steps_line(id, &refs)).unwrap();
        ids.push((kind, id));
    }
    // idle past the TTL: the sweep must spill every session to disk
    std::thread::sleep(ttl + std::time::Duration::from_millis(700));
    client.call(r#"{"op":"stats"}"#).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 0, "idle sessions still resident");
    assert_eq!(
        stats.usize_field("spilled").unwrap(),
        kinds.len(),
        "sessions destroyed, not spilled"
    );
    // the per-backend breakdown attributes each spilled blob to its kind
    for kind in kinds {
        let spilled_of = stats
            .get("backends")
            .and_then(|b| b.get(kind))
            .and_then(|e| e.get("spilled"))
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("stats reply lacks backends.{kind}.spilled"));
        assert_eq!(spilled_of, 1, "kind {kind}: wrong per-backend spilled count");
    }

    // touching a spilled session restores it transparently — and the
    // resumed stream is bitwise the control's
    for (kind, id) in ids {
        let all: Vec<Vec<f32>> = head.iter().chain(tail.iter()).cloned().collect();
        let want = control_outputs(kind, channels, &all);
        let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
        let reply = client.call(&steps_line(id, &refs)).unwrap();
        assert_eq!(
            reply.usize_field("t").unwrap(),
            all.len(),
            "kind {kind}: t must resume where the stream left off"
        );
        assert_eq!(
            ys_as_f64(&reply),
            want[head.len()..].to_vec(),
            "kind {kind}: resumed outputs diverged from the never-evicted control"
        );
    }
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&spill);
}

#[test]
fn fold_kernel_backends_serve_end_to_end_bitwise() {
    // the fold-kernel tentpole at the TCP level: every non-Aaren kernel
    // serves create → steps → snapshot → restore → steps with each
    // output bitwise the local scalar control session's, and `stats`
    // breaks the session population down per backend
    let channels = 3;
    let (addr, server) = start(channels, 2);
    let mut client = Client::connect(&addr).unwrap();
    let head: Vec<Vec<f32>> = (0..9).map(|i| dyadic_token(i, channels)).collect();
    let tail: Vec<Vec<f32>> = (0..6).map(|i| dyadic_token(80 + i, channels)).collect();
    let all: Vec<Vec<f32>> = head.iter().chain(tail.iter()).cloned().collect();
    for kind in ["mingru", "minlstm", "avg_attn"] {
        let want = control_outputs(kind, channels, &all);
        // the backend shorthand creates the kernel without a "kind" field
        let id = client
            .call(&format!(r#"{{"op":"create","backend":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
        let reply = client.call(&steps_line(id, &refs)).unwrap();
        assert_eq!(ys_as_f64(&reply), want[..head.len()].to_vec(), "kind {kind}: head diverged");
        let snap = client.call(&format!(r#"{{"op":"snapshot","id":{id}}}"#)).unwrap();
        assert_eq!(snap.str_field("kind").unwrap(), kind);
        assert_eq!(snap.usize_field("t").unwrap(), head.len());
        assert_eq!(snap.usize_field("channels").unwrap(), channels);
        let blob = snap.str_field("state").unwrap().to_string();
        let restored = client
            .call(&format!(r#"{{"op":"restore","state":"{blob}"}}"#))
            .unwrap();
        assert_eq!(restored.str_field("kind").unwrap(), kind);
        let twin = restored.usize_field("id").unwrap();
        let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
        for sid in [id, twin] {
            let reply = client.call(&steps_line(sid, &refs)).unwrap();
            assert_eq!(reply.usize_field("t").unwrap(), all.len(), "kind {kind}");
            assert_eq!(
                ys_as_f64(&reply),
                want[head.len()..].to_vec(),
                "kind {kind}: session {sid} tail diverged from the scalar control"
            );
        }
    }
    // original + restored twin per kernel; stats names them all
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 6);
    for kind in ["mingru", "minlstm", "avg_attn"] {
        let resident_of = stats
            .get("backends")
            .and_then(|b| b.get(kind))
            .and_then(|e| e.get("resident"))
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("stats reply lacks backends.{kind}.resident"));
        assert_eq!(resident_of, 2, "kind {kind}: wrong per-backend resident count");
    }
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn foreign_width_snapshot_restores_and_streams_bitwise() {
    // a snapshot whose channel width differs from the server's
    // --channels must restore (into its own lane set — width migration
    // keeps lane residency now) and resume bitwise where it stood
    use aaren::serve::{NativeScanSession, StreamSession};
    use aaren::util::b64;
    let blob_channels = 3;
    let head: Vec<Vec<f32>> = (0..6).map(|i| dyadic_token(i, blob_channels)).collect();
    let tail: Vec<Vec<f32>> = (0..5).map(|i| dyadic_token(60 + i, blob_channels)).collect();
    let mut control = NativeScanSession::new(blob_channels);
    for x in &head {
        control.step(x).unwrap();
    }
    let blob = b64::encode(&StreamSession::snapshot(&control).unwrap());
    let want: Vec<Vec<f64>> = tail.iter().map(|x| as_f64(&control.step(x).unwrap())).collect();

    // the server runs 5-channel natives; the 3-channel blob keeps ITS width
    let (addr, server) = start(5, 2);
    let mut client = Client::connect(&addr).unwrap();
    let restored = client.call(&format!(r#"{{"op":"restore","state":"{blob}"}}"#)).unwrap();
    assert_eq!(restored.usize_field("channels").unwrap(), blob_channels);
    let id = restored.usize_field("id").unwrap();
    let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
    let reply = client.call(&steps_line(id, &refs)).unwrap();
    assert_eq!(reply.usize_field("t").unwrap(), head.len() + tail.len());
    assert_eq!(ys_as_f64(&reply), want, "foreign-width stream diverged from the control");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn spilled_sessions_survive_a_server_restart() {
    let channels = 2;
    let spill = scratch_dir("spill-restart");
    let mut cfg = base_cfg(channels, 2);
    cfg.session_ttl = Some(std::time::Duration::from_millis(200));
    cfg.spill_dir = Some(spill.clone());

    let head: Vec<Vec<f32>> = (0..5).map(|i| dyadic_token(i, channels)).collect();
    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
    client.call(&steps_line(id, &refs)).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(900));
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("spilled").unwrap(), 1);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();

    // a fresh server over the same spill dir adopts the snapshot: the
    // session resumes, and new ids never collide with the surviving one
    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("spilled").unwrap(), 1, "snapshot not adopted after restart");
    let fresh =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    assert!(fresh > id, "id counter must be seeded past surviving snapshots");
    let tail: Vec<Vec<f32>> = (0..4).map(|i| dyadic_token(30 + i, channels)).collect();
    let all: Vec<Vec<f32>> = head.iter().chain(tail.iter()).cloned().collect();
    let want = control_outputs("aaren", channels, &all);
    let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
    let reply = client.call(&steps_line(id, &refs)).unwrap();
    assert_eq!(reply.usize_field("t").unwrap(), all.len());
    assert_eq!(ys_as_f64(&reply), want[head.len()..].to_vec());
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&spill);
}

/// Kill-on-drop wrapper so a failing assertion can't leak a spawned
/// server process.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn snapshot_migrates_across_two_server_processes() {
    // the migration acceptance path, with REAL process isolation: spawn
    // the aaren binary twice, snapshot a stream on server A, restore it
    // on server B, and check B continues bitwise where A stood
    use std::io::BufRead;
    use std::process::{Command, Stdio};

    let channels = 4;
    let spawn_server = || {
        let mut child = Command::new(env!("CARGO_BIN_EXE_aaren"))
            .args(["serve", "--addr", "127.0.0.1:0", "--channels", "4", "--shards", "2"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn aaren serve");
        let mut banner = String::new();
        std::io::BufReader::new(child.stdout.take().expect("stdout piped"))
            .read_line(&mut banner)
            .expect("read listen banner");
        let addr = banner
            .split("listening on ")
            .nth(1)
            .and_then(|rest| rest.split_whitespace().next())
            .unwrap_or_else(|| panic!("no address in banner {banner:?}"))
            .parse::<std::net::SocketAddr>()
            .expect("parse listen address");
        (ChildGuard(child), addr)
    };

    let head: Vec<Vec<f32>> = (0..10).map(|i| dyadic_token(i, channels)).collect();
    let tail: Vec<Vec<f32>> = (0..6).map(|i| dyadic_token(200 + i, channels)).collect();
    let all: Vec<Vec<f32>> = head.iter().chain(tail.iter()).cloned().collect();
    let want = control_outputs("aaren", channels, &all);

    // server process A: stream the head, snapshot, shut down
    let (proc_a, addr_a) = spawn_server();
    let mut client = Client::connect(&addr_a).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
    client.call(&steps_line(id, &refs)).unwrap();
    let snap = client.call(&format!(r#"{{"op":"snapshot","id":{id}}}"#)).unwrap();
    let blob = snap.str_field("state").unwrap().to_string();
    assert_eq!(snap.usize_field("t").unwrap(), head.len());
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    drop(proc_a); // server A is gone; only the blob survives

    // server process B: restore the blob, stream the tail
    let (proc_b, addr_b) = spawn_server();
    let mut client = Client::connect(&addr_b).unwrap();
    let restored = client
        .call(&format!(r#"{{"op":"restore","state":"{blob}"}}"#))
        .unwrap();
    let twin = restored.usize_field("id").unwrap();
    assert_eq!(restored.usize_field("t").unwrap(), head.len());
    let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
    let reply = client.call(&steps_line(twin, &refs)).unwrap();
    assert_eq!(reply.usize_field("t").unwrap(), all.len());
    assert_eq!(
        ys_as_f64(&reply),
        want[head.len()..].to_vec(),
        "migrated stream diverged from the uninterrupted control"
    );
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    drop(proc_b);
}

#[test]
fn duplicate_create_id_is_rejected_over_tcp() {
    let (addr, server) = start(2, 2);
    let mut client = Client::connect(&addr).unwrap();
    let r = client.call(r#"{"op":"create","kind":"aaren","id":5}"#).unwrap();
    assert_eq!(r.usize_field("id").unwrap(), 5);
    client.call(&step_line(5, &[0.5, 0.25])).unwrap();
    // same id again: structured error, live state untouched
    let r = client.call_raw(r#"{"op":"create","kind":"tf","id":5}"#).unwrap();
    let (_, err) = wire_error(&r).unwrap();
    assert!(err.contains("already exists"), "got: {err}");
    let r = client.call(&step_line(5, &[0.5, 0.25])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 2, "duplicate create clobbered the session");
    // auto-assigned ids skip past claimed ones instead of colliding
    let fresh = client
        .call(r#"{"op":"create","kind":"aaren"}"#)
        .unwrap()
        .usize_field("id")
        .unwrap();
    assert!(fresh > 5, "auto id {fresh} collides with the claimed range");
    // explicit ids are a native-tier feature
    let r = client.call_raw(r#"{"op":"create","kind":"aaren","backend":"hlo","id":7}"#).unwrap();
    assert!(r.get("error").is_some());
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn large_steps_blocks_stream_partial_replies() {
    // satellite: a steps block beyond STEPS_REPLY_BLOCK is answered in
    // fixed-size partial reply lines, not one giant materialized reply —
    // and the streamed outputs are exactly the per-step control's
    use aaren::serve::STEPS_REPLY_BLOCK;
    let channels = 2;
    let (addr, server) = start(channels, 1);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let n = 2 * STEPS_REPLY_BLOCK + 176;
    let tokens: Vec<Vec<f32>> = (0..n).map(|i| dyadic_token(i, channels)).collect();
    let refs: Vec<&[f32]> = tokens.iter().map(|x| x.as_slice()).collect();
    let replies = client.call_streamed(&steps_line(id, &refs)).unwrap();
    assert_eq!(replies.len(), 3, "expected two partial lines plus the final one");
    let want = control_outputs("aaren", channels, &tokens);
    let mut off = 0usize;
    for (li, reply) in replies.iter().enumerate() {
        let last = li == replies.len() - 1;
        assert_eq!(
            matches!(reply.get("partial"), Some(Json::Bool(true))),
            !last,
            "line {li}: wrong partial flag"
        );
        let ys = ys_as_f64(reply);
        assert!(ys.len() <= STEPS_REPLY_BLOCK, "line {li}: reply block exceeds the bound");
        assert_eq!(
            ys,
            want[off..off + ys.len()].to_vec(),
            "line {li}: streamed outputs diverged from per-step control"
        );
        off += ys.len();
        assert_eq!(reply.usize_field("t").unwrap(), off, "line {li}: t mid-stream");
    }
    assert_eq!(off, n, "streamed lines must cover every token exactly once");
    // the session advanced exactly n tokens, once
    let r = client.call(&step_line(id, &dyadic_token(999, channels))).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), n + 1);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn resident_lane_churn_with_spill_and_restore_stays_bitwise() {
    // the resident-lane tentpole end to end: sessions churn lanes
    // (create / close / create reuses freed lanes), idle past the TTL
    // (spilling lane state through the codec), and resume on touch —
    // every surviving stream must stay bitwise the never-evicted
    // control's. One shard so every session shares one LaneSet.
    let channels = 3;
    let ttl = std::time::Duration::from_millis(400);
    let spill = scratch_dir("lane-churn");
    let mut cfg = base_cfg(channels, 1);
    cfg.session_ttl = Some(ttl);
    cfg.spill_dir = Some(spill.clone());
    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();

    // three sessions fill lanes 0..2; each streams a distinct head
    let mut ids = Vec::new();
    for k in 0..3usize {
        let id = client
            .call(r#"{"op":"create","kind":"aaren"}"#)
            .unwrap()
            .usize_field("id")
            .unwrap();
        let head: Vec<Vec<f32>> = (0..5 + k).map(|i| dyadic_token(10 * k + i, channels)).collect();
        let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
        client.call(&steps_line(id, &refs)).unwrap();
        ids.push((id, head));
    }
    // close the middle session: its lane becomes a reusable hole…
    let (closed, _) = ids.remove(1);
    client.call(&format!(r#"{{"op":"close","id":{closed}}}"#)).unwrap();
    // …which the next create claims
    let reused = client
        .call(r#"{"op":"create","kind":"aaren"}"#)
        .unwrap()
        .usize_field("id")
        .unwrap();
    let head: Vec<Vec<f32>> = (0..7).map(|i| dyadic_token(40 + i, channels)).collect();
    let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
    client.call(&steps_line(reused, &refs)).unwrap();
    ids.push((reused, head));

    // idle past the TTL: every resident lane spills to disk
    std::thread::sleep(ttl + std::time::Duration::from_millis(700));
    client.call(r#"{"op":"stats"}"#).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 0, "sessions still resident");
    assert_eq!(stats.usize_field("spilled").unwrap(), 3, "lane states not spilled");

    // touch restores each into a fresh lane, bitwise where it left off
    for (id, head) in &ids {
        let tail: Vec<Vec<f32>> = (0..6).map(|i| dyadic_token(70 + i, channels)).collect();
        let all: Vec<Vec<f32>> = head.iter().chain(tail.iter()).cloned().collect();
        let want = control_outputs("aaren", channels, &all);
        let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
        let reply = client.call(&steps_line(*id, &refs)).unwrap();
        assert_eq!(reply.usize_field("t").unwrap(), all.len(), "session {id}: t diverged");
        assert_eq!(
            ys_as_f64(&reply),
            want[head.len()..].to_vec(),
            "session {id}: resumed lane stream diverged from the control"
        );
    }
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&spill);
}

#[test]
fn graceful_shutdown_spills_sessions_and_a_restart_resumes_them() {
    // ROADMAP PR 4 follow-up: with --spill-dir, `shutdown` must spill
    // what is resident (no TTL involved) so a restarted server resumes
    // every stream bitwise
    let channels = 2;
    let spill = scratch_dir("shutdown-spill");
    let mut cfg = base_cfg(channels, 2);
    cfg.spill_dir = Some(spill.clone());

    let head: Vec<Vec<f32>> = (0..6).map(|i| dyadic_token(i, channels)).collect();
    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
    client.call(&steps_line(id, &refs)).unwrap();
    // shutdown immediately: the session is resident, never TTL-swept
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();

    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(
        stats.usize_field("spilled").unwrap(),
        1,
        "graceful shutdown dropped the resident session instead of spilling it"
    );
    let tail: Vec<Vec<f32>> = (0..5).map(|i| dyadic_token(20 + i, channels)).collect();
    let all: Vec<Vec<f32>> = head.iter().chain(tail.iter()).cloned().collect();
    let want = control_outputs("aaren", channels, &all);
    let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
    let reply = client.call(&steps_line(id, &refs)).unwrap();
    assert_eq!(reply.usize_field("t").unwrap(), all.len());
    assert_eq!(
        ys_as_f64(&reply),
        want[head.len()..].to_vec(),
        "stream across a graceful shutdown diverged from the control"
    );
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&spill);
}

#[test]
fn restore_with_an_explicit_target_id_over_tcp() {
    let channels = 2;
    let (addr, server) = start(channels, 2);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    for i in 0..4 {
        client.call(&step_line(id, &dyadic_token(i, channels))).unwrap();
    }
    let snap = client.call(&format!(r#"{{"op":"snapshot","id":{id}}}"#)).unwrap();
    let blob = snap.str_field("state").unwrap().to_string();
    // restore AT a chosen id: the twin adopts it and serves there
    let restored = client
        .call(&format!(r#"{{"op":"restore","state":"{blob}","id":77}}"#))
        .unwrap();
    assert_eq!(restored.usize_field("id").unwrap(), 77);
    assert_eq!(restored.usize_field("t").unwrap(), 4);
    let r = client.call(&step_line(77, &dyadic_token(9, channels))).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 5);
    // a second restore at the same id is a structured collision error
    let r = client
        .call_raw(&format!(r#"{{"op":"restore","state":"{blob}","id":77}}"#))
        .unwrap();
    let (_, err) = wire_error(&r).unwrap();
    assert!(err.contains("already exists"), "got: {err}");
    // the original target keeps its stream position
    let r = client.call(&step_line(77, &dyadic_token(10, channels))).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 6, "collision clobbered the target session");
    // auto ids skip past the claimed one
    let fresh = client
        .call(r#"{"op":"create","kind":"aaren"}"#)
        .unwrap()
        .usize_field("id")
        .unwrap();
    assert!(fresh > 77, "auto id {fresh} collides with the claimed range");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn protocol_errors_are_replies_not_disconnects() {
    let (addr, server) = start(2, 1);
    let mut client = Client::connect(&addr).unwrap();
    // unknown session, unknown kind, bad json: all error replies
    let r = client.call_raw(r#"{"op":"step","id":99,"x":[0.0,0.0]}"#).unwrap();
    assert!(r.get("error").is_some());
    let r = client.call_raw(r#"{"op":"create","kind":"mamba"}"#).unwrap();
    assert!(r.get("error").is_some());
    // a kernel-name backend that contradicts the kind field is refused
    let r = client.call_raw(r#"{"op":"create","kind":"tf","backend":"mingru"}"#).unwrap();
    assert!(r.get("error").is_some());
    // ...but a matching pair, or backend alone, is fine
    let ok = client.call(r#"{"op":"create","kind":"minlstm","backend":"minlstm"}"#).unwrap();
    assert!(ok.usize_field("id").is_ok());
    let r = client.call_raw("not json").unwrap();
    assert!(r.get("error").is_some());
    // the hlo backend is absent from the default build
    let r = client.call_raw(r#"{"op":"create","kind":"aaren","backend":"hlo"}"#).unwrap();
    assert!(r.get("error").is_some());
    // ...and the connection still serves afterwards
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let r = client.call(&step_line(id, &[0.5, 0.5])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 1);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn oversized_frame_closes_only_the_offending_connection() {
    let channels = 2;
    let mut cfg = base_cfg(channels, 1);
    cfg.max_frame_bytes = 1024;
    let (addr, server) = start_cfg(&cfg);

    // client A: a live stream that must survive B's abuse untouched
    let tokens: Vec<Vec<f32>> = (0..12).map(|i| dyadic_token(i, channels)).collect();
    let want = control_outputs("aaren", channels, &tokens);
    let mut a = Client::connect(&addr).unwrap();
    let id = a.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    for (t, x) in tokens.iter().take(6).enumerate() {
        let r = a.call(&step_line(id, x)).unwrap();
        assert_eq!(r.usize_field("t").unwrap(), t + 1);
    }

    // client B: one frame far past the cap, no newline needed to trip it
    use std::io::{BufRead, BufReader, Write};
    let mut b = std::net::TcpStream::connect(addr).unwrap();
    b.write_all(&[b'x'; 8192]).unwrap();
    b.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(b.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let r = Json::parse(line.trim()).unwrap();
    let (kind, msg) = wire_error(&r).unwrap();
    assert_eq!(kind, "frame_too_large", "got: {msg}");
    assert!(msg.contains("1024"), "limit missing from message: {msg}");
    // the error line is final: the offender's connection closes
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "offender must be disconnected");

    // an in-cap frame on a fresh connection still gets a plain error reply
    let mut c = Client::connect(&addr).unwrap();
    let r = c.call_raw("garbage that is not json").unwrap();
    let (kind, _) = wire_error(&r).unwrap();
    assert_eq!(kind, "error");

    // client A's stream continues bitwise against the control
    for (t, x) in tokens.iter().enumerate().skip(6) {
        let r = a.call(&step_line(id, x)).unwrap();
        assert_eq!(r.usize_field("t").unwrap(), t + 1);
        let y: Vec<f64> = r
            .get("y")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(y, want[t], "token {t} diverged after B's abuse");
    }
    a.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

/// The histogram object the `metrics` reply carries for `name`, if any.
fn metrics_hist(reply: &Json, name: &str) -> Option<Json> {
    reply.get("histograms").and_then(|h| h.get(name)).cloned()
}

fn hist_count(reply: &Json, name: &str) -> usize {
    metrics_hist(reply, name)
        .and_then(|h| h.get("count").and_then(Json::as_usize))
        .unwrap_or(0)
}

#[test]
fn metrics_op_reports_histograms_and_flight_events() {
    // the observability acceptance at the wire: after a round-trip with a
    // spill, a restore and a forced quarantine, the `metrics` op must
    // report well-formed per-op and per-stage histograms (p50 ≤ p99 ≤
    // max, non-empty buckets) and the flight recorder must hold the
    // lifecycle events with the right session ids
    if cfg!(feature = "obs-noop") {
        return; // instrumentation compiled out — nothing to assert
    }
    aaren::fault::silence_injected_panics();
    let channels = 3;
    let spill = scratch_dir("metrics");
    let mut cfg = base_cfg(channels, 2);
    cfg.spill_dir = Some(spill.clone());
    // session 1 (the first auto-assigned id) is the sacrificial panic
    // victim; session 2 carries the real mingru workload
    cfg.fault = Some(aaren::fault::FaultPlan::new(0x0B5).panic_on_step(1));
    let (addr, server) = start_cfg(&cfg);
    let mut client = Client::connect(&addr).unwrap();

    let victim = client
        .call(r#"{"op":"create","kind":"aaren"}"#)
        .unwrap()
        .usize_field("id")
        .unwrap();
    assert_eq!(victim, 1, "auto ids must start at 1 for the fault plan to hit");
    let id = client
        .call(r#"{"op":"create","kind":"mingru"}"#)
        .unwrap()
        .usize_field("id")
        .unwrap();
    let head: Vec<Vec<f32>> = (0..8).map(|i| dyadic_token(i, channels)).collect();
    let refs: Vec<&[f32]> = head.iter().map(|x| x.as_slice()).collect();
    client.call(&steps_line(id, &refs)).unwrap();

    // the injected panic fires on the victim's first step and must come
    // back as the structured quarantine kind — and land in the recorder
    let r = client.call_raw(&step_line(victim, &dyadic_token(0, channels))).unwrap();
    let (kind, _) = wire_error(&r).unwrap();
    assert_eq!(kind, aaren::fault::KIND_QUARANTINED);

    // drain spills the workload session; the next steps restores it
    client.call(&format!(r#"{{"op":"drain","id":{id}}}"#)).unwrap();
    let tail: Vec<Vec<f32>> = (0..5).map(|i| dyadic_token(30 + i, channels)).collect();
    let refs: Vec<&[f32]> = tail.iter().map(|x| x.as_slice()).collect();
    let reply = client.call(&steps_line(id, &refs)).unwrap();
    assert_eq!(reply.usize_field("t").unwrap(), head.len() + tail.len());

    let m = client.call(r#"{"op":"metrics"}"#).unwrap();

    // per-op wire latency: two `steps` round-trips, well-formed shape
    let steps = metrics_hist(&m, "op_steps").expect("metrics reply lacks op_steps");
    assert!(steps.usize_field("count").unwrap() >= 2);
    let p50 = steps.usize_field("p50_ns").unwrap();
    let p99 = steps.usize_field("p99_ns").unwrap();
    let max = steps.usize_field("max_ns").unwrap();
    assert!(p50 > 0, "a TCP round-trip cannot take zero time");
    assert!(p50 <= p99 && p99 <= max, "percentiles out of order: {p50} {p99} {max}");
    let Some(Json::Obj(buckets)) = steps.get("buckets").cloned() else {
        panic!("op_steps histogram lacks a buckets object");
    };
    assert!(!buckets.is_empty(), "op_steps buckets must be non-empty");

    // internal stages: the executor, kernel and both spill legs all saw
    // work this session, so their histograms must be populated
    for stage in [
        "queue_wait",
        "exec_drain",
        "kernel_fold",
        "spill_encode",
        "spill_write",
        "restore_read",
        "restore_decode",
    ] {
        assert!(hist_count(&m, stage) > 0, "stage {stage} recorded nothing");
    }

    // the flight recorder holds the lifecycle with the right ids
    let events = m.get("events").and_then(Json::as_arr).expect("metrics reply lacks events");
    for e in events {
        for field in ["seq", "ts_ms", "kind", "id", "shard"] {
            assert!(e.get(field).is_some(), "event {e} lacks the {field} field");
        }
    }
    let has = |kind: &str, id: usize| {
        events.iter().any(|e| {
            e.get("kind").and_then(Json::as_str) == Some(kind)
                && e.get("id").and_then(Json::as_usize) == Some(id)
        })
    };
    assert!(has("create", victim) && has("create", id), "create events missing");
    assert!(has("quarantine", victim), "the forced panic must log a quarantine event");
    assert!(has("spill", id), "the drain must log a spill event");
    assert!(has("restore", id), "the touch after the drain must log a restore event");

    let logged = m
        .get("counters")
        .and_then(|c| c.get("events_logged"))
        .and_then(Json::as_usize)
        .expect("metrics reply lacks counters.events_logged");
    assert!(logged >= 5, "expected at least 5 recorded events, got {logged}");

    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&spill);
}

#[test]
fn fleet_metrics_merges_member_histograms_bucket_wise() {
    // fleet-level observability: the router's `metrics` must equal the
    // bucket-wise merge of its members' histograms (counts sum, maxes
    // max, percentiles re-derived — never summed), append its own
    // proxy-hop timings, and `fleet_stats` must report per-member
    // liveness (health state + last_heartbeat_ms age)
    if cfg!(feature = "obs-noop") {
        return;
    }
    let channels = 2;
    let (a_addr, a_srv) = start(channels, 1);
    let (b_addr, b_srv) = start(channels, 1);
    let fcfg = aaren::fleet::FleetConfig {
        addr: "127.0.0.1:0".to_string(),
        members: vec![a_addr.to_string(), b_addr.to_string()],
        hb_interval: std::time::Duration::from_millis(50),
        io_timeout: Some(std::time::Duration::from_secs(10)),
        ..aaren::fleet::FleetConfig::default()
    };
    let fleet = aaren::fleet::Fleet::bind(&fcfg).unwrap();
    let faddr = fleet.local_addr().unwrap();
    let frun = std::thread::spawn(move || fleet.run());
    let mut client = Client::connect(&faddr).unwrap();

    // 8 sessions spread over the ring, one steps block each
    let tokens: Vec<Vec<f32>> = (0..4).map(|i| dyadic_token(i, channels)).collect();
    let refs: Vec<&[f32]> = tokens.iter().map(|x| x.as_slice()).collect();
    let n_sessions = 8;
    for _ in 0..n_sessions {
        let id = client
            .call(r#"{"op":"create","kind":"mingru"}"#)
            .unwrap()
            .usize_field("id")
            .unwrap();
        client.call(&steps_line(id, &refs)).unwrap();
    }

    // give the 50ms heartbeat loop time to stamp every member
    std::thread::sleep(std::time::Duration::from_millis(500));
    let fs = client.call(r#"{"op":"fleet_stats"}"#).unwrap();
    let members = fs.get("members").and_then(Json::as_arr).expect("fleet_stats lacks members");
    assert_eq!(members.len(), 2);
    for m in members {
        assert_eq!(m.str_field("health").unwrap(), "alive");
        let age = m
            .get("last_heartbeat_ms")
            .and_then(Json::as_f64)
            .expect("member lacks a numeric last_heartbeat_ms");
        assert!(age >= 0.0, "heartbeat age cannot be negative: {age}");
    }

    // ground truth: each member's own metrics, asked directly
    let mut member_counts = 0usize;
    let mut member_max = 0usize;
    for addr in [&a_addr, &b_addr] {
        let mut c = Client::connect(addr).unwrap();
        let direct = c.call(r#"{"op":"metrics"}"#).unwrap();
        member_counts += hist_count(&direct, "op_steps");
        if let Some(h) = metrics_hist(&direct, "op_steps") {
            member_max = member_max.max(h.usize_field("max_ns").unwrap_or(0));
        }
    }
    assert_eq!(member_counts, n_sessions, "every steps block lands on exactly one member");

    let merged = client.call(r#"{"op":"metrics"}"#).unwrap();
    let steps = metrics_hist(&merged, "op_steps").expect("fleet metrics lacks op_steps");
    assert_eq!(
        steps.usize_field("count").unwrap(),
        member_counts,
        "merged count must be the sum of the member counts"
    );
    assert_eq!(
        steps.usize_field("max_ns").unwrap(),
        member_max,
        "merged max must be the max of the member maxes"
    );
    let p50 = steps.usize_field("p50_ns").unwrap();
    let p99 = steps.usize_field("p99_ns").unwrap();
    assert!(p50 <= p99 && p99 <= member_max, "re-derived percentiles out of order");

    // the router's own domain rides along: every create/steps crossed
    // the proxy hop
    assert!(
        hist_count(&merged, "fleet_proxy") >= 2 * n_sessions,
        "fleet_proxy histogram missing or undercounted"
    );
    // member events carry their origin tag
    let events = merged.get("events").and_then(Json::as_arr).expect("fleet metrics lacks events");
    assert!(events.iter().all(|e| e.get("member").is_some()), "untagged fleet event");
    assert!(
        events.iter().any(|e| e.get("kind").and_then(Json::as_str) == Some("create")),
        "member create events must surface in the fleet rollup"
    );

    client.call(r#"{"op":"shutdown"}"#).unwrap();
    frun.join().unwrap().unwrap();
    a_srv.join().unwrap().unwrap();
    b_srv.join().unwrap().unwrap();
}
