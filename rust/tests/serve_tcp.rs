//! Loopback TCP integration tests for the rust-native serving stack.
//! These run in the **default feature set** (no XLA): the paper's
//! Figure-5 serving story end to end — create → step × k → stats → close
//! over line-delimited JSON, with Aaren `state_bytes` constant in stream
//! length and the tf KV session surviving past the largest cache bucket.

use aaren::serve::server::{Client, ServeConfig, Server};
use aaren::serve::TF_BUCKETS;
use aaren::util::json::Json;

type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn start_with_ttl(
    channels: usize,
    shards: usize,
    session_ttl: Option<std::time::Duration>,
) -> (std::net::SocketAddr, ServerHandle) {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards,
        session_ttl,
        artifacts: None,
    };
    let server = Server::bind(&cfg).expect("bind loopback");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

fn start(channels: usize, shards: usize) -> (std::net::SocketAddr, ServerHandle) {
    start_with_ttl(channels, shards, None)
}

fn step_line(id: usize, x: &[f32]) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}]}}"#, xs.join(","))
}

#[test]
fn aaren_session_streams_with_constant_state() {
    let (addr, server) = start(4, 2);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let mut bytes = Vec::new();
    for t in 0..64 {
        let r = client.call(&step_line(id, &[0.1, 0.2, -0.3, 0.4])).unwrap();
        assert_eq!(r.usize_field("t").unwrap(), t + 1);
        assert_eq!(r.get("y").and_then(Json::as_arr).unwrap().len(), 4);
        bytes.push(r.usize_field("state_bytes").unwrap());
    }
    assert!(bytes.windows(2).all(|w| w[0] == w[1]), "aaren state must be constant: {bytes:?}");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn tf_session_state_grows_and_survives_past_largest_bucket() {
    let (addr, server) = start(1, 1);
    let mut client = Client::connect(&addr).unwrap();
    let id = client.call(r#"{"op":"create","kind":"tf"}"#).unwrap().usize_field("id").unwrap();
    let largest = TF_BUCKETS[TF_BUCKETS.len() - 1];
    let mut first_bytes = 0;
    let mut last_bytes = 0;
    for t in 0..largest + 40 {
        let r = client.call(&step_line(id, &[1.0])).unwrap();
        last_bytes = r.usize_field("state_bytes").unwrap();
        if t == 0 {
            first_bytes = last_bytes;
        }
        assert_eq!(r.usize_field("t").unwrap(), t + 1);
    }
    // the stream crossed every bucket and kept going past the largest one
    assert!(last_bytes > first_bytes, "kv cache must grow: {first_bytes} -> {last_bytes}");
    assert_eq!(last_bytes, 2 * (2 * largest) * 4, "one geometric doubling past the ladder");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn stats_aggregate_across_shards_and_close_frees_sessions() {
    let (addr, server) = start(4, 3);
    let mut client = Client::connect(&addr).unwrap();
    let mut ids = Vec::new();
    for kind in ["aaren", "tf", "aaren", "tf"] {
        let id = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        ids.push(id);
    }
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 4);
    // two aaren ((2 + channels) f32s each) + two tf (first bucket each)
    let aaren_bytes = (2 + 4) * 4;
    let tf_bytes = 2 * TF_BUCKETS[0] * 4 * 4;
    let total = stats.usize_field("total_state_bytes").unwrap();
    assert_eq!(total, 2 * aaren_bytes + 2 * tf_bytes);
    for id in &ids[..2] {
        client.call(&format!(r#"{{"op":"close","id":{id}}}"#)).unwrap();
    }
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 2);
    // a second connection reaches the same sessions
    let mut other = Client::connect(&addr).unwrap();
    let r = other.call(&step_line(ids[3], &[0.0, 0.0, 0.0, 0.0])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 1);
    other.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

fn steps_line(id: usize, tokens: &[&[f32]]) -> String {
    let rows: Vec<String> = tokens
        .iter()
        .map(|x| {
            let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!(r#"{{"op":"steps","id":{id},"xs":[{}]}}"#, rows.join(","))
}

#[test]
fn steps_block_matches_individual_step_calls() {
    // satellite property: a `steps` block over TCP is indistinguishable
    // from the same tokens sent as N individual `step` calls — outputs,
    // t and state_bytes all line up, for both session kinds.
    let (addr, server) = start(3, 2);
    let mut client = Client::connect(&addr).unwrap();
    let tokens: Vec<Vec<f32>> = (0..12)
        .map(|i| vec![0.25 * i as f32 - 1.0, (i % 3) as f32, -0.5 * i as f32])
        .collect();
    for kind in ["aaren", "tf"] {
        let one = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        let block = client
            .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
            .unwrap()
            .usize_field("id")
            .unwrap();
        let mut want = Vec::new();
        let mut want_bytes = 0;
        for x in &tokens {
            let r = client.call(&step_line(one, x)).unwrap();
            let y: Vec<f64> = r
                .get("y")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            want.push(y);
            want_bytes = r.usize_field("state_bytes").unwrap();
        }
        let refs: Vec<&[f32]> = tokens.iter().map(|x| x.as_slice()).collect();
        let r = client.call(&steps_line(block, &refs)).unwrap();
        let got: Vec<Vec<f64>> = r
            .get("ys")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
            .collect();
        assert_eq!(got, want, "kind {kind}: batched outputs diverge from per-step outputs");
        assert_eq!(r.usize_field("t").unwrap(), tokens.len(), "kind {kind}");
        assert_eq!(r.usize_field("state_bytes").unwrap(), want_bytes, "kind {kind}");
    }
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn steps_errors_are_replies_and_empty_blocks_are_noops() {
    let (addr, server) = start(2, 1);
    let mut client = Client::connect(&addr).unwrap();
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    // wrong width: error reply, session unharmed
    let r = client.call_raw(&steps_line(id, &[&[1.0, 2.0][..], &[3.0][..]])).unwrap();
    assert!(r.get("error").is_some(), "ragged rows must be rejected");
    let r = client.call_raw(&steps_line(id, &[&[1.0][..], &[2.0][..]])).unwrap();
    assert!(r.get("error").is_some(), "width-1 rows on a 2-channel session must be rejected");
    // an empty block is a no-op that still gets a well-formed reply
    let r = client.call(&steps_line(id, &[])).unwrap();
    assert_eq!(r.get("ys").and_then(Json::as_arr).unwrap().len(), 0);
    assert_eq!(r.usize_field("t").unwrap(), 0);
    // the session still works afterwards
    let r = client.call(&step_line(id, &[0.5, -0.5])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 1);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn idle_sessions_are_evicted_after_the_ttl() {
    // ROADMAP PR-2 follow-up: a client that disconnects without `close`
    // must not leak its sessions forever once a TTL is configured.
    let ttl = std::time::Duration::from_millis(500);
    let (addr, server) = start_with_ttl(2, 2, Some(ttl));
    {
        let mut doomed = Client::connect(&addr).unwrap();
        doomed.call(r#"{"op":"create","kind":"aaren"}"#).unwrap();
        doomed.call(r#"{"op":"create","kind":"tf"}"#).unwrap();
        let stats = doomed.call(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(stats.usize_field("sessions").unwrap(), 2);
        // client drops without close
    }
    std::thread::sleep(ttl + std::time::Duration::from_millis(600));
    let mut client = Client::connect(&addr).unwrap();
    // the stats fan-out drains every shard, triggering the sweep; the
    // first reply may still count pre-sweep sessions, so read twice
    client.call(r#"{"op":"stats"}"#).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 0, "idle sessions must be swept");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn protocol_errors_are_replies_not_disconnects() {
    let (addr, server) = start(2, 1);
    let mut client = Client::connect(&addr).unwrap();
    // unknown session, unknown kind, bad json: all error replies
    let r = client.call_raw(r#"{"op":"step","id":99,"x":[0.0,0.0]}"#).unwrap();
    assert!(r.get("error").is_some());
    let r = client.call_raw(r#"{"op":"create","kind":"mamba"}"#).unwrap();
    assert!(r.get("error").is_some());
    let r = client.call_raw("not json").unwrap();
    assert!(r.get("error").is_some());
    // the hlo backend is absent from the default build
    let r = client.call_raw(r#"{"op":"create","kind":"aaren","backend":"hlo"}"#).unwrap();
    assert!(r.get("error").is_some());
    // ...and the connection still serves afterwards
    let id =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    let r = client.call(&step_line(id, &[0.5, 0.5])).unwrap();
    assert_eq!(r.usize_field("t").unwrap(), 1);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    server.join().unwrap().unwrap();
}
