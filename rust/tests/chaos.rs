//! Chaos suite: the fault-containment acceptance tests from the
//! robustness issues, in the **default feature set** (no XLA).
//!
//! Three attack surfaces:
//!
//! * In-process, a server under a seeded [`FaultPlan`] (IO errors, torn
//!   writes, forced panics, delays) serves concurrent streaming clients
//!   across all four fold kernels. The contract under fire is a
//!   DICHOTOMY: every stream either completes bitwise against an
//!   offline control, or ends in a structured error kind — never a hang
//!   (client IO timeouts enforce this) and never a silently wrong
//!   output.
//! * Out-of-process, a spawned server is SIGKILLed mid-load and
//!   restarted on the same spill directory. Sessions whose snapshots hit
//!   disk resume bitwise; everything else answers with a structured
//!   error. With torn writes injected under the kill, damaged blobs must
//!   surface as `corrupt_snapshot` — not as wrong outputs.
//! * Fleet: three spawned backends behind an `aaren fleet` router, one
//!   SIGKILLed under multi-kernel load. Every stream resumes bitwise on
//!   a survivor (failover replay from the shared spill dir for the
//!   victim's sessions, lazy restore for the survivors') or dies with a
//!   structured kind.
//!
//! Fault decisions are drawn from per-site decision streams keyed on
//! (seed, site tag), so the injected sequence at any one site is
//! replayable even though thread interleaving decides which session
//! lands on which roll. The assertions are therefore written against
//! the containment contract, not against one interleaving.

use std::collections::BTreeSet;
use std::time::Duration;

use aaren::fault::{FaultPlan, KIND_CORRUPT_SNAPSHOT, KIND_NO_SESSION, KIND_QUARANTINED};
use aaren::scan::KernelKind;
use aaren::serve::server::{Client, ServeConfig, Server};
use aaren::serve::{NativeScanSession, StreamSession, RETRY_AFTER_CAP_MS, RETRY_AFTER_MS};
use aaren::util::json::Json;

/// Exactly-representable token values (multiples of 0.25 in a small
/// range) so JSON f64 → f32 → printed f64 round-trips are lossless and
/// stream comparisons can demand BIT equality.
fn dyadic_token(i: usize, channels: usize) -> Vec<f32> {
    (0..channels).map(|c| ((i * 7 + c * 3) % 13) as f32 * 0.25 - 1.5).collect()
}

/// Offline control: the outputs an undisturbed `kind` stream over
/// `tokens` must produce (exact, as f64 rows).
fn control_outputs(kind: KernelKind, channels: usize, tokens: &[Vec<f32>]) -> Vec<Vec<f64>> {
    let mut session = NativeScanSession::new_kernel(kind, channels);
    tokens
        .iter()
        .map(|x| session.step(x).unwrap().iter().map(|v| *v as f64).collect())
        .collect()
}

/// Per-kernel controls, indexed like [`KernelKind::ALL`].
fn controls_per_kind(channels: usize, tokens: &[Vec<f32>]) -> Vec<Vec<Vec<f64>>> {
    KernelKind::ALL.iter().map(|&k| control_outputs(k, channels, tokens)).collect()
}

/// The kernel a chaos session id streams on: ids cycle through the
/// whole family so every backend sees quarantine, spill churn and kill
/// recovery.
fn kind_of_id(id: u64) -> KernelKind {
    KernelKind::ALL[(id as usize + KernelKind::ALL.len() - 1) % KernelKind::ALL.len()]
}

fn step_line(id: u64, x: &[f32]) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}]}}"#, xs.join(","))
}

fn y_as_f64(reply: &Json) -> Vec<f64> {
    reply
        .get("y")
        .and_then(Json::as_arr)
        .expect("y")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// Unique scratch dir (std has no tempdir crate).
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "aaren-chaos-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// How one chaos stream ended.
#[derive(Debug)]
enum Outcome {
    /// every token acked in order, every output bitwise == control
    Complete,
    /// a structured error ended the stream at this kind
    Structured(String),
}

/// Drive session `id` through `tokens` one step at a time, retrying
/// `overloaded` sheds after their hint and treating any other error
/// reply as the stream's terminal, structured outcome. Panics on the
/// two containment violations: a reply that is wrong (t out of order or
/// outputs diverging from the control) and an unstructured transport
/// failure (hang → IO timeout, closed connection, unparseable reply).
fn drive_stream(
    addr: &std::net::SocketAddr,
    id: u64,
    kind: KernelKind,
    tokens: &[Vec<f32>],
    want: &[Vec<f64>],
    pause_every: usize,
    pause: Duration,
) -> Outcome {
    let mut client = Client::connect(addr).unwrap();
    client.set_io_timeout(Some(Duration::from_secs(20))).unwrap();
    let create = format!(r#"{{"op":"create","kind":"{}","id":{id}}}"#, kind.wire_name());
    let r = client.call_raw(&create).unwrap();
    assert!(r.get("error").is_none(), "create {id} failed: {r:?}");
    for (t, x) in tokens.iter().enumerate() {
        if pause_every > 0 && t > 0 && t % pause_every == 0 {
            std::thread::sleep(pause);
        }
        let reply = loop {
            let r = client.call_raw(&step_line(id, x)).unwrap();
            match aaren::serve::wire_error(&r) {
                None => break Ok(r),
                Some((kind, msg)) if kind == "overloaded" => {
                    // the hint is occupancy-priced now: anywhere in
                    // [floor, cap] is a valid shed, missing is not
                    let hint = r
                        .get("error")
                        .and_then(|e| e.usize_field("retry_after_ms").ok())
                        .unwrap_or_else(|| panic!("overloaded without a backoff hint: {msg}"));
                    assert!(
                        (RETRY_AFTER_MS as usize..=RETRY_AFTER_CAP_MS as usize).contains(&hint),
                        "hint {hint}ms outside [{RETRY_AFTER_MS}, {RETRY_AFTER_CAP_MS}]"
                    );
                    std::thread::sleep(Duration::from_millis(hint as u64));
                }
                Some((kind, msg)) => break Err((kind, msg)),
            }
        };
        match reply {
            Ok(r) => {
                assert_eq!(
                    r.usize_field("t").unwrap(),
                    t + 1,
                    "session {id} stream position silently diverged"
                );
                assert_eq!(
                    y_as_f64(&r),
                    want[t],
                    "session {id} token {t} output diverged from the control"
                );
            }
            Err((kind, _msg)) => return Outcome::Structured(kind),
        }
    }
    Outcome::Complete
}

/// The in-process half of the acceptance criterion: a seeded fault plan
/// (IO errors + torn spill writes + two forced panics + delays) under
/// concurrent clients, TTL spills and an LRU resident cap — with the
/// session population cycling through ALL FOUR fold kernels, so
/// quarantine and spill churn are exercised per backend. Every stream
/// must complete bitwise against its own kernel's control or die
/// structured; the forced panics must quarantine exactly their victims.
#[test]
fn seeded_chaos_streams_complete_bitwise_or_die_structured() {
    let channels = 4;
    let tokens: Vec<Vec<f32>> = (0..40).map(|i| dyadic_token(i, channels)).collect();
    let controls = controls_per_kind(channels, &tokens);

    // rates are deliberately low: the forced panics guarantee faults
    // fire, while innocents survive often enough that "at least one
    // stream completes" cannot flake (each session crosses the
    // spill/restore boundary a handful of times)
    let dir = scratch_dir("seeded");
    let plan = FaultPlan::new(0xC4A05)
        .io_errors(0.01)
        .torn_writes(0.05)
        .delays(0.10, Duration::from_millis(1))
        .panic_on_step(3)
        .panic_on_step(8);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards: 2,
        session_ttl: Some(Duration::from_millis(60)),
        spill_dir: Some(dir.clone()),
        max_resident_sessions: Some(8),
        queue_depth: 8,
        fault: Some(plan),
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let run = std::thread::spawn(move || server.run());

    // 12 sessions across 4 client threads; the pauses outlive the TTL so
    // every stream crosses the spill/restore boundary repeatedly
    let ids: Vec<u64> = (1..=12).collect();
    let outcomes: Vec<(u64, Outcome)> = std::thread::scope(|scope| {
        let handles: Vec<_> = ids
            .chunks(3)
            .map(|chunk| {
                let (tokens, controls) = (&tokens, &controls);
                scope.spawn(move || {
                    chunk
                        .iter()
                        .map(|&id| {
                            // a 150ms pause every 10 tokens: well past
                            // the 60ms TTL, so the idle-wake sweep
                            // spills the session mid-stream each time
                            let kind = kind_of_id(id);
                            let want = &controls
                                [KernelKind::ALL.iter().position(|&k| k == kind).unwrap()];
                            let out = drive_stream(
                                &addr,
                                id,
                                kind,
                                tokens,
                                want,
                                10,
                                Duration::from_millis(150),
                            );
                            (id, out)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    let structured_kinds: BTreeSet<&str> =
        ["quarantined", "corrupt_snapshot", "no_session", "error"].into();
    let mut completed = 0;
    for (id, outcome) in &outcomes {
        match outcome {
            Outcome::Complete => completed += 1,
            Outcome::Structured(kind) => assert!(
                structured_kinds.contains(kind.as_str()),
                "session {id} died with unexpected kind {kind:?}"
            ),
        }
    }
    // the forced panics condemn their victims — deterministically
    for victim in [3u64, 8] {
        let (_, outcome) = outcomes.iter().find(|(id, _)| *id == victim).unwrap();
        assert!(
            matches!(outcome, Outcome::Structured(k) if k == KIND_QUARANTINED),
            "forced-panic victim {victim} should be quarantined, got {outcome:?}"
        );
    }
    // the fault rates are low enough that losing every innocent stream
    // has negligible probability — survivors prove the faults were
    // CONTAINED, not just reported
    assert!(completed >= 1, "no stream survived the chaos run: {outcomes:?}");

    let mut client = Client::connect(&addr).unwrap();
    client.set_io_timeout(Some(Duration::from_secs(20))).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert!(stats.usize_field("quarantined").unwrap() >= 2, "stats lost the quarantines");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    run.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill-on-drop wrapper so a failing assertion can't leak a spawned
/// server process.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Spawn the real binary and parse its listen banner.
fn spawn_server(extra: &[&str]) -> (ChildGuard, std::net::SocketAddr) {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    let mut child = Command::new(env!("CARGO_BIN_EXE_aaren"))
        .args(["serve", "--addr", "127.0.0.1:0", "--channels", "4"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn aaren serve");
    let mut banner = String::new();
    std::io::BufReader::new(child.stdout.take().expect("stdout piped"))
        .read_line(&mut banner)
        .expect("read listen banner");
    let addr = banner
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner {banner:?}"))
        .parse::<std::net::SocketAddr>()
        .expect("parse listen address");
    (ChildGuard(child), addr)
}

/// The out-of-process half: SIGKILL a loaded server, restart it on the
/// same spill directory, and demand the dichotomy — a session either
/// resumes BITWISE from its spilled snapshot or answers a structured
/// error; no third outcome (hang, wrong output, clobbered id) exists.
/// Sessions cycle through all four fold kernels, so every backend's
/// spill blobs cross the kill/restart boundary. `fault` optionally
/// injects torn spill writes under the kill, which must then surface as
/// `corrupt_snapshot`, never as silent damage.
fn kill_restart_dichotomy(tag: &str, fault: Option<&str>) {
    let channels = 4;
    let head: Vec<Vec<f32>> = (0..8).map(|i| dyadic_token(i, channels)).collect();
    let all: Vec<Vec<f32>> = (0..9).map(|i| dyadic_token(i, channels)).collect();
    let controls = controls_per_kind(channels, &all);
    let want_of = |id: u64| -> &Vec<Vec<f64>> {
        &controls[KernelKind::ALL.iter().position(|&k| k == kind_of_id(id)).unwrap()]
    };
    let dir = scratch_dir(tag);
    let dir_s = dir.to_str().unwrap().to_string();

    let mut args = vec!["--spill-dir", &dir_s, "--session-ttl-secs", "1", "--shards", "2"];
    if let Some(spec) = fault {
        args.extend_from_slice(&["--fault-plan", spec]);
    }
    let (child, addr) = spawn_server(&args);
    let mut client = Client::connect(&addr).unwrap();
    client.set_io_timeout(Some(Duration::from_secs(20))).unwrap();
    let ids: Vec<u64> = (1..=8).collect();
    for &id in &ids {
        let kind = kind_of_id(id).wire_name();
        client.call(&format!(r#"{{"op":"create","kind":"{kind}","id":{id}}}"#)).unwrap();
        for x in &head {
            client.call(&step_line(id, x)).unwrap();
        }
    }
    // outlive the TTL so the sweep spills every session to disk, then
    // put the server back under load and kill it with no warning
    std::thread::sleep(Duration::from_millis(2500));
    for &id in &ids[..2] {
        // these touches restore ids 1–2 from disk mid-flight; their
        // snapshots are retired, so after the kill they must be GONE
        // (structured), not resurrected stale
        let _ = client.call_raw(&step_line(id, &all[8]));
    }
    drop(child); // SIGKILL, mid-load — no graceful shutdown path runs

    let (child, addr) = spawn_server(&args);
    let mut client = Client::connect(&addr).unwrap();
    client.set_io_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut resumed = 0;
    for &id in &ids {
        let r = client.call_raw(&step_line(id, &all[8])).unwrap();
        match aaren::serve::wire_error(&r) {
            None => {
                // resumed: it must stand EXACTLY where the spilled
                // snapshot left it — head folded, token 8 just applied
                assert_eq!(r.usize_field("t").unwrap(), 9, "session {id} resumed at wrong t");
                assert_eq!(y_as_f64(&r), want_of(id)[8], "session {id} resumed off the control");
                resumed += 1;
            }
            Some((kind, msg)) => {
                let kinds = [KIND_NO_SESSION, KIND_CORRUPT_SNAPSHOT, KIND_QUARANTINED];
                assert!(
                    kinds.contains(&kind.as_str()),
                    "session {id} died unstructured: {kind} ({msg})"
                );
            }
        }
    }
    if fault.is_none() {
        // no injected damage: everything the sweep spilled and the load
        // did not retire (ids 3–8) resumes bitwise
        assert!(resumed >= 6, "only {resumed} of 6 spilled sessions resumed");
    }
    // fresh ids are seeded past every surviving snapshot, so recovery
    // cannot clobber a spilled stream
    let fresh =
        client.call(r#"{"op":"create","kind":"aaren"}"#).unwrap().usize_field("id").unwrap();
    assert!(fresh as u64 > 8, "auto id {fresh} collides with recovered sessions");
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    drop(child);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sigkill_under_load_spilled_sessions_resume_bitwise() {
    kill_restart_dichotomy("kill", None);
}

#[test]
fn sigkill_with_torn_spill_writes_stays_structured() {
    // every other spill put persists a truncated blob and lies about it;
    // after the restart those blobs MUST answer corrupt_snapshot (and
    // the rest resume bitwise) — the lying-disk acceptance path
    kill_restart_dichotomy("torn", Some("seed=11,torn=0.5"));
}

/// Spawn an `aaren fleet` router over `members` and parse its banner.
fn spawn_fleet(
    members: &[std::net::SocketAddr],
    spill: &str,
) -> (ChildGuard, std::net::SocketAddr) {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    let members: Vec<String> = members.iter().map(|a| a.to_string()).collect();
    let mut child = Command::new(env!("CARGO_BIN_EXE_aaren"))
        .args(["fleet", "--addr", "127.0.0.1:0", "--members", &members.join(",")])
        .args(["--spill-dir", spill])
        // an aggressive detector so the test's failover completes in
        // well under a second: probe every 50ms, dead after 2 misses
        .args(["--hb-interval-ms", "50", "--hb-timeout-ms", "250", "--hb-misses", "2"])
        .args(["--io-timeout-secs", "20"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn aaren fleet");
    let mut banner = String::new();
    std::io::BufReader::new(child.stdout.take().expect("stdout piped"))
        .read_line(&mut banner)
        .expect("read fleet banner");
    let addr = banner
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner {banner:?}"))
        .parse::<std::net::SocketAddr>()
        .expect("parse fleet listen address");
    (ChildGuard(child), addr)
}

/// Step `id` through the fleet with a deadline-bounded retry loop:
/// `overloaded` sheds (including the router's own failover-in-progress
/// sheds) are retried after their hint; any other error is the stream's
/// structured outcome.
fn fleet_step(
    client: &mut Client,
    id: u64,
    x: &[f32],
    deadline: Duration,
) -> Result<Json, (String, String)> {
    let start = std::time::Instant::now();
    loop {
        let r = client.call_raw(&step_line(id, x)).unwrap();
        match aaren::serve::wire_error(&r) {
            None => return Ok(r),
            Some((kind, msg)) if kind == "overloaded" => {
                assert!(
                    start.elapsed() < deadline,
                    "session {id} still shedding after {deadline:?}: {msg}"
                );
                let hint = r
                    .get("error")
                    .and_then(|e| e.usize_field("retry_after_ms").ok())
                    .unwrap_or_else(|| panic!("overloaded without a backoff hint: {msg}"));
                std::thread::sleep(Duration::from_millis(hint as u64));
            }
            Some(err) => return Err(err),
        }
    }
}

/// THE fleet acceptance test (ROADMAP item 6): three backends behind a
/// router, sessions across all four kernels, one backend SIGKILLed.
/// Every stream must resume bitwise on a survivor — failover replay
/// from the shared spill dir covers the victim's sessions, lazy restore
/// covers the survivors' — or die with a structured kind. Never silent
/// corruption, never a hang.
#[test]
fn fleet_sigkill_one_member_streams_resume_bitwise_or_die_structured() {
    let channels = 4;
    let head: Vec<Vec<f32>> = (0..8).map(|i| dyadic_token(i, channels)).collect();
    let all: Vec<Vec<f32>> = (0..9).map(|i| dyadic_token(i, channels)).collect();
    let controls = controls_per_kind(channels, &all);
    let dir = scratch_dir("fleet");
    let dir_s = dir.to_str().unwrap().to_string();

    // three backends sharing ONE spill dir — the failover replay source
    let backend_args = ["--spill-dir", &dir_s, "--session-ttl-secs", "1", "--shards", "2"];
    let mut backends: Vec<(ChildGuard, std::net::SocketAddr)> =
        (0..3).map(|_| spawn_server(&backend_args)).collect();
    let member_addrs: Vec<std::net::SocketAddr> = backends.iter().map(|(_, a)| *a).collect();
    let (fleet, fleet_addr) = spawn_fleet(&member_addrs, &dir_s);

    let mut client = Client::connect(&fleet_addr).unwrap();
    client.set_io_timeout(Some(Duration::from_secs(20))).unwrap();

    // 16 streams across the 4 kernels, ids assigned by the fleet
    let mut sessions: Vec<(u64, KernelKind)> = Vec::new();
    for i in 0..16usize {
        let kind = KernelKind::ALL[i % KernelKind::ALL.len()];
        let r = client
            .call(&format!(r#"{{"op":"create","kind":"{}"}}"#, kind.wire_name()))
            .unwrap();
        sessions.push((r.usize_field("id").unwrap() as u64, kind));
    }
    for &(id, _) in &sessions {
        for x in &head {
            fleet_step(&mut client, id, x, Duration::from_secs(5)).expect("head token failed");
        }
    }

    // outlive the TTL so every backend's sweep spills every session to
    // the shared dir, then SIGKILL one member with no warning
    std::thread::sleep(Duration::from_millis(2500));
    let victim_addr = member_addrs[0].to_string();
    drop(backends.remove(0));

    // every stream steps token 8: the detector (50ms probes, 2 misses)
    // plus the replay must finish well inside the retry deadline
    let mut resumed = 0;
    for &(id, kind) in &sessions {
        let want = &controls[KernelKind::ALL.iter().position(|&k| k == kind).unwrap()];
        match fleet_step(&mut client, id, &all[8], Duration::from_secs(15)) {
            Ok(r) => {
                assert_eq!(r.usize_field("t").unwrap(), 9, "session {id} resumed at wrong t");
                assert_eq!(y_as_f64(&r), want[8], "session {id} resumed off the control");
                resumed += 1;
            }
            Err((kind, msg)) => {
                let kinds = [KIND_NO_SESSION, KIND_CORRUPT_SNAPSHOT, KIND_QUARANTINED];
                assert!(
                    kinds.contains(&kind.as_str()),
                    "session {id} died unstructured: {kind} ({msg})"
                );
            }
        }
    }
    // every session was cleanly spilled before the kill, so the full
    // population resumes: survivors' sessions lazily from their own
    // stores, the victim's via the router's failover replay
    assert_eq!(resumed, sessions.len(), "only {resumed}/{} streams resumed", sessions.len());

    // the router's own view agrees: one dead member, a completed
    // failover, and every failed-over session resumed
    let fs = client.call(r#"{"op":"fleet_stats"}"#).unwrap();
    let members = fs.get("members").and_then(Json::as_arr).expect("members array");
    let health_of = |addr: &str| -> String {
        members
            .iter()
            .find(|m| m.get("addr").and_then(Json::as_str) == Some(addr))
            .and_then(|m| m.get("health").and_then(Json::as_str))
            .expect("member health")
            .to_string()
    };
    assert_eq!(health_of(&victim_addr), "dead", "victim not detected: {fs:?}");
    for alive in &member_addrs[1..] {
        assert_eq!(health_of(&alive.to_string()), "alive", "survivor misdiagnosed: {fs:?}");
    }
    assert_eq!(fs.usize_field("failovers").unwrap(), 1, "failover count: {fs:?}");
    let failed_over = fs.usize_field("failed_over_sessions").unwrap();
    assert!(failed_over >= 1, "the victim owned no sessions — ring balance broke: {fs:?}");
    assert_eq!(
        fs.usize_field("failover_resumed").unwrap(),
        failed_over,
        "failover lost sessions: {fs:?}"
    );

    // the fleet still takes new work after the loss
    let fresh = client.call(r#"{"op":"create","kind":"mingru"}"#).unwrap();
    let fresh_id = fresh.usize_field("id").unwrap() as u64;
    assert!(sessions.iter().all(|&(id, _)| id != fresh_id), "fresh id collided");
    fleet_step(&mut client, fresh_id, &all[0], Duration::from_secs(5)).expect("fresh stream");

    // shutdown through the fleet stops the survivors too
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    drop(fleet);
    drop(backends);
    let _ = std::fs::remove_dir_all(&dir);
}
