//! Integration tests over the real AOT artifacts (require `make artifacts`
//! to have been run — they are skipped with a notice if artifacts/ is
//! missing, so plain `cargo test` works in a fresh checkout).
//!
//! These enforce DESIGN.md's equivalence contracts 5 and 6 end-to-end:
//! streaming through the rust session manager reproduces the parallel
//! forward pass, for both Aaren (O(1) state) and the Transformer KV-cache
//! baseline (including bucket migration). Plus: training steps reduce the
//! loss through the full rust→XLA round-trip for every domain family.

use aaren::coordinator::Trainer;
use aaren::runtime::exec::{literal_to_f32, Engine, HostTensor};
use aaren::runtime::manifest::Role;
use aaren::runtime::params::ParamStore;
use aaren::serve::session::{Session, StreamModel};
use aaren::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    for candidate in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(candidate);
        if p.join("stream_aaren_fwd.manifest.json").exists() {
            return Some(p);
        }
    }
    eprintln!("NOTE: artifacts/ not found — run `make artifacts`; skipping integration test");
    None
}

/// Run the parallel forward artifact on a fresh-params model.
fn parallel_forward(engine: &mut Engine, name: &str, xs: &[f32], shape: &[usize]) -> Vec<f32> {
    let fwd = engine.load(name).unwrap();
    let store = ParamStore::load(&fwd.manifest).unwrap();
    let mut args = Vec::new();
    let mut pi = 0;
    for arg in &fwd.manifest.args {
        match arg.role {
            Role::Param => {
                args.push(
                    HostTensor::F32(arg.shape.clone(), store.params[pi].clone())
                        .to_literal()
                        .unwrap(),
                );
                pi += 1;
            }
            _ => args.push(HostTensor::F32(shape.to_vec(), xs.to_vec()).to_literal().unwrap()),
        }
    }
    literal_to_f32(&fwd.execute(&args).unwrap()[0]).unwrap()
}

#[test]
fn aaren_streaming_equals_parallel_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let model = StreamModel::load_aaren(&mut engine).unwrap();
    let c = model.channels;
    let fwd = engine.load("stream_aaren_fwd").unwrap();
    let n = fwd.manifest.meta_usize("seq", 64);

    let mut rng = Rng::new(11);
    let mut xs = vec![0.0f32; n * c];
    rng.fill_gaussian(&mut xs, 1.0);
    let parallel = parallel_forward(&mut engine, "stream_aaren_fwd", &xs, &[1, n, c]);

    let mut session = Session::new_aaren(&model).unwrap();
    let state_bytes_start = session.state_bytes();
    let mut max_err = 0.0f32;
    for t in 0..n {
        let y = session.step(&model, &xs[t * c..(t + 1) * c]).unwrap();
        for (a, b) in y.iter().zip(&parallel[t * c..(t + 1) * c]) {
            max_err = max_err.max((a - b).abs());
        }
    }
    assert!(max_err < 1e-4, "streaming vs parallel max err {max_err}");
    // the O(1)-memory claim, enforced: state size never changed
    assert_eq!(session.state_bytes(), state_bytes_start);
}

#[test]
fn tf_kv_streaming_equals_parallel_forward_with_migration() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let model = StreamModel::load_tf(&mut engine).unwrap();
    let c = model.channels;
    let fwd = engine.load("stream_tf_fwd").unwrap();
    let n = fwd.manifest.meta_usize("seq", 64);

    let mut rng = Rng::new(12);
    let mut xs = vec![0.0f32; n * c];
    rng.fill_gaussian(&mut xs, 1.0);
    let parallel = parallel_forward(&mut engine, "stream_tf_fwd", &xs, &[1, n, c]);

    let mut session = Session::new_tf(&model).unwrap();
    let bytes_start = session.state_bytes();
    let mut max_err = 0.0f32;
    for t in 0..n {
        let y = session.step(&model, &xs[t * c..(t + 1) * c]).unwrap();
        for (a, b) in y.iter().zip(&parallel[t * c..(t + 1) * c]) {
            max_err = max_err.max((a - b).abs());
        }
    }
    // n=64 crosses the 32-bucket boundary: migration happened and memory grew
    assert!(session.state_bytes() > bytes_start, "kv cache should have grown");
    assert!(max_err < 1e-4, "kv streaming vs parallel max err {max_err}");
}

#[test]
fn train_step_reduces_loss_for_every_domain_family() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(3);

    // stream family (aaren) — 30 steps on a fixed batch must cut the loss
    let module = engine.load("stream_aaren_train").unwrap();
    let b = module.manifest.meta_usize("batch", 8);
    let n = module.manifest.meta_usize("seq", 64);
    let c = module.manifest.meta_usize("channels", 8);
    let mut xs = vec![0.0f32; b * n * c];
    rng.fill_gaussian(&mut xs, 1.0);
    let mut trainer = Trainer::new(module).unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..30 {
        let loss = trainer
            .step(&[HostTensor::F32(vec![b, n, c], xs.clone())])
            .unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "stream loss did not drop: {first} -> {last}");

    // tsc family (tf) — same contract through the classification head
    let module = engine.load("tsc_tf_train").unwrap();
    let b = module.manifest.meta_usize("batch", 16);
    let n = module.manifest.meta_usize("seq", 96);
    let c = module.manifest.meta_usize("channels", 8);
    let mut xs = vec![0.0f32; b * n * c];
    rng.fill_gaussian(&mut xs, 1.0);
    let labels: Vec<i32> = (0..b).map(|i| (i % 4) as i32).collect();
    let mut trainer = Trainer::new(module).unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..30 {
        let loss = trainer
            .step(&[
                HostTensor::F32(vec![b, n, c], xs.clone()),
                HostTensor::I32(vec![b], labels.clone()),
            ])
            .unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "tsc loss did not drop: {first} -> {last}");
}

#[test]
fn trained_params_flow_into_eval_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    use aaren::coordinator::experiments::{run_tsc, Kind};
    use aaren::data::tsc::TscDataset;
    // short run on the easiest dataset: accuracy must comfortably beat
    // chance (1/10), proving train->eval param transfer works
    let r = run_tsc(&mut engine, Kind::Aaren, TscDataset::ArabicDigits, 60, 5).unwrap();
    assert!(r.acc > 30.0, "acc {}% not above chance — param flow broken?", r.acc);
}

#[test]
fn checkpoint_roundtrip_preserves_behaviour() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let module = engine.load("stream_aaren_train").unwrap();
    let b = module.manifest.meta_usize("batch", 8);
    let n = module.manifest.meta_usize("seq", 64);
    let c = module.manifest.meta_usize("channels", 8);
    let mut rng = Rng::new(9);
    let mut xs = vec![0.0f32; b * n * c];
    rng.fill_gaussian(&mut xs, 1.0);
    let mut trainer = Trainer::new(module.clone()).unwrap();
    for _ in 0..5 {
        trainer.step(&[HostTensor::F32(vec![b, n, c], xs.clone())]).unwrap();
    }
    let trained = trainer.sync_store().unwrap();
    let tmp = std::env::temp_dir().join("aaren_ckpt_test.bin");
    trained.save(&tmp).unwrap();
    let restored = ParamStore::load_from(&module.manifest, &tmp).unwrap();
    assert_eq!(restored.params, trained.params);
}

#[test]
fn session_manager_protocol_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    // full loopback TCP round-trip over the compiled-HLO backend,
    // selected per session with "backend":"hlo"
    use aaren::serve::server::{Client, ServeConfig, Server};
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels: 8,
        shards: 1,
        artifacts: Some(dir),
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let th = std::thread::spawn(move || server.run());

    let mut client = Client::connect(&addr).unwrap();
    let id = client
        .call(r#"{"op":"create","kind":"aaren","backend":"hlo"}"#)
        .unwrap()
        .usize_field("id")
        .unwrap();
    let mut rng = Rng::new(4);
    let mut last_bytes = 0;
    for _ in 0..8 {
        let xs: Vec<String> = (0..8).map(|_| format!("{}", rng.gaussian() as f32)).collect();
        let r = client
            .call(&format!(r#"{{"op":"step","id":{id},"x":[{}]}}"#, xs.join(",")))
            .unwrap();
        let bytes = r.usize_field("state_bytes").unwrap();
        if last_bytes != 0 {
            assert_eq!(bytes, last_bytes, "aaren session memory must be constant");
        }
        last_bytes = bytes;
        assert!(r.get("y").is_some());
    }
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 1);
    client.call(&format!(r#"{{"op":"close","id":{id}}}"#)).unwrap();
    let stats = client.call(r#"{"op":"stats"}"#).unwrap();
    assert_eq!(stats.usize_field("sessions").unwrap(), 0);
    client.call(r#"{"op":"shutdown"}"#).unwrap();
    th.join().unwrap().unwrap();
}
