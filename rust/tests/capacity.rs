//! Capacity suite: the million-session harness's correctness contract,
//! in the default feature set (no XLA).
//!
//! Two acceptance tests from the capacity issue:
//!
//! * **Deterministic replay** — the same seed + trace config replayed
//!   against two fresh servers must deliver identical arrival
//!   sequences, identical per-op aggregate counts, and leave sampled
//!   sessions in bitwise-identical states (compared via their wire
//!   `snapshot` blobs). Only time-independent quantities are compared:
//!   sheds, retries, and spill/restore counts depend on real thread
//!   timing, but WHICH ops ran with WHICH tokens does not — and
//!   because spill → restore is bitwise, the surviving session states
//!   can't tell how often they cycled through the store.
//! * **Soak** — a five-figure session population churned through
//!   resident ↔ spill ↔ restore under a tight LRU cap and a short TTL.
//!   Sampled sessions must answer a probe burst bitwise-equal to boxed
//!   client-side controls fed the identical token history, every
//!   failure must be a structured wire kind, and nothing may be
//!   quarantined (no fault plan is installed).
//!
//! `AAREN_SOAK_SESSIONS` overrides the soak population (default
//! 10_000) for heavier out-of-CI runs.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::Duration;

use aaren::loadgen::{slot_id, slot_kind, ArrivalKind, LoadConfig, TokenBank};
use aaren::serve::{Client, NativeScanSession, ServeConfig, Server, StreamSession};
use aaren::util::json::Json;

/// Spill tier on tmpfs when the platform offers it: the soak writes
/// spill files by the thousand, and fsync on rotating CI disks would
/// turn a correctness test into an I/O benchmark.
fn spill_base() -> PathBuf {
    let shm = PathBuf::from("/dev/shm");
    if shm.is_dir() {
        shm
    } else {
        std::env::temp_dir()
    }
}

/// A loopback server shaped for residency churn: resident cap far
/// below the live population, short idle TTL, spill store on disk.
fn spawn_server(channels: usize, cap: usize, tag: &str) -> (SocketAddr, PathBuf) {
    let spill = spill_base().join(format!("aaren-capacity-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill);
    std::fs::create_dir_all(&spill).expect("spill dir");
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards: 4,
        session_ttl: Some(Duration::from_millis(200)),
        spill_dir: Some(spill.clone()),
        max_resident_sessions: Some(cap),
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    std::thread::spawn(move || server.run());
    (addr, spill)
}

fn shutdown(addr: &SocketAddr, spill: &PathBuf) {
    if let Ok(mut c) = Client::connect(addr) {
        let _ = c.call(r#"{"op":"shutdown"}"#);
    }
    let _ = std::fs::remove_dir_all(spill);
}

/// The sampled (kept-open) slots of a run, thinned to at most `max`.
fn sampled_slots(cfg: &LoadConfig, max: usize) -> Vec<usize> {
    let kept: Vec<usize> =
        (0..cfg.sessions).filter(|s| cfg.keep_every != 0 && s % cfg.keep_every == 0).collect();
    let stride = kept.len().div_ceil(max).max(1);
    kept.into_iter().step_by(stride).collect()
}

/// Wire snapshot of one session: the base64 state blob and the token
/// clock. Blob equality IS bitwise state equality (the codec is a
/// deterministic function of the session state).
fn snapshot(client: &mut Client, slot: usize) -> (String, usize) {
    let id = slot_id(slot);
    let reply = client
        .call(&format!(r#"{{"op":"snapshot","id":{id}}}"#))
        .unwrap_or_else(|e| panic!("snapshot of slot {slot}: {e:#}"));
    (reply.str_field("state").expect("state").to_string(), reply.usize_field("t").expect("t"))
}

#[test]
fn replay_is_deterministic_across_fresh_servers() {
    let mut cfg = LoadConfig::quick();
    cfg.sessions = 3_000;
    cfg.workers = 6;
    cfg.bursts = 3;
    cfg.batch = 8;
    cfg.channels = 6;
    cfg.seed = 1234;
    cfg.keep_every = 83;
    cfg.kind = ArrivalKind::OnOff;

    let mut blobs: Vec<Vec<(String, usize)>> = Vec::new();
    let mut counts: Vec<(u64, u64, u64, u64)> = Vec::new();
    for run_tag in ["replay-a", "replay-b"] {
        let (addr, spill) = spawn_server(cfg.channels, 256, run_tag);
        let mut run_cfg = cfg.clone();
        run_cfg.addr = Some(addr.to_string());
        let report = aaren::loadgen::run(&run_cfg).expect("load run");
        assert!(report.failures.is_empty(), "{run_tag} failures: {:?}", report.failures);
        assert_eq!(report.quarantined, 0, "{run_tag} quarantined sessions");
        counts.push((report.created, report.steps_ops, report.tokens, report.closed));
        let mut client = Client::connect(&addr).expect("connect");
        blobs.push(sampled_slots(&cfg, 24).iter().map(|&s| snapshot(&mut client, s)).collect());
        shutdown(&addr, &spill);
        // NOT compared: report.sheds / retries / spills / restores /
        // latency percentiles — those depend on wall-clock thread
        // timing. The open-loop trace fixes the op stream, not the
        // schedule's collisions with the LRU cap.
    }
    assert_eq!(counts[0], counts[1], "per-op aggregate counts diverged between replays");
    assert_eq!(blobs[0].len(), blobs[1].len());
    for (i, (a, b)) in blobs[0].iter().zip(blobs[1].iter()).enumerate() {
        assert_eq!(a.1, b.1, "sampled session {i}: token clocks diverged");
        assert_eq!(a.0, b.0, "sampled session {i}: snapshot blobs diverged (state not bitwise)");
    }
}

#[test]
fn soak_churns_sessions_through_residency_bitwise() {
    let sessions = std::env::var("AAREN_SOAK_SESSIONS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(10_000);
    let mut cfg = LoadConfig::quick();
    cfg.sessions = sessions;
    cfg.workers = 8;
    cfg.bursts = 3;
    cfg.batch = 8;
    cfg.channels = 8;
    cfg.seed = 7;
    cfg.keep_every = 173;

    let cap = (sessions / 20).max(64);
    let (addr, spill) = spawn_server(cfg.channels, cap, "soak");
    let mut run_cfg = cfg.clone();
    run_cfg.addr = Some(addr.to_string());
    let report = aaren::loadgen::run(&run_cfg).expect("soak run");

    // every recorded failure must be a structured wire kind — and with
    // no fault plan installed, there should be none at all
    let known = ["quarantined", "overloaded", "corrupt_snapshot", "no_session", "error"];
    for kind in report.failures.keys() {
        assert!(known.contains(&kind.as_str()), "unstructured failure kind {kind:?}");
    }
    assert!(report.failures.is_empty(), "soak failures: {:?}", report.failures);
    assert_eq!(report.quarantined, 0, "quarantine must stay empty without a fault plan");
    assert_eq!(report.created as usize, sessions);
    assert_eq!(report.steps_ops as usize, sessions * cfg.bursts);
    assert_eq!(report.tokens as usize, sessions * cfg.bursts * cfg.batch);
    assert!(
        report.spills > 0 && report.restores > 0,
        "a {cap}-session cap under {sessions} sessions must cycle the spill tier \
         (spills {}, restores {})",
        report.spills,
        report.restores
    );

    // sampled sessions must answer a probe burst bitwise-equal to a
    // boxed client-side control fed the identical token history —
    // TokenBank purity lets the test recompute every token the server
    // ever saw for a slot
    let bank = TokenBank::new(cfg.seed ^ 0x746f6b, cfg.channels);
    let mut client = Client::connect(&addr).expect("connect");
    for slot in sampled_slots(&cfg, 32) {
        let mut control = NativeScanSession::new_kernel(slot_kind(slot), cfg.channels);
        for row in bank.history(slot, cfg.bursts, cfg.batch).chunks_exact(cfg.channels) {
            control.step(row).expect("control step");
        }
        let probe = bank.tokens(slot, cfg.bursts, cfg.batch);
        let expected: Vec<Vec<f64>> = probe
            .chunks_exact(cfg.channels)
            .map(|row| control.step(row).expect("probe").iter().map(|v| *v as f64).collect())
            .collect();
        let id = slot_id(slot);
        let rows: Vec<String> = probe
            .chunks_exact(cfg.channels)
            .map(|row| {
                let cells: Vec<String> = row.iter().map(|v| format!("{}", *v as f64)).collect();
                format!("[{}]", cells.join(","))
            })
            .collect();
        let reply = client
            .call(&format!(r#"{{"op":"steps","id":{id},"xs":[{}]}}"#, rows.join(",")))
            .unwrap_or_else(|e| panic!("probe of slot {slot}: {e:#}"));
        let ys = reply.get("ys").and_then(Json::as_arr).expect("ys");
        assert_eq!(ys.len(), expected.len(), "slot {slot}: probe row count");
        for (r, (got, want)) in ys.iter().zip(expected.iter()).enumerate() {
            let got = got.as_arr().expect("row");
            assert_eq!(got.len(), want.len());
            for (c, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                let g = g.as_f64().expect("num");
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "slot {slot} probe row {r} ch {c}: server {g} vs control {w} — \
                     resident↔spill↔restore cycling broke bitwise equality"
                );
            }
        }
    }
    shutdown(&addr, &spill);
}
