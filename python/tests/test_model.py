# pytest: L2 model contracts — shapes, the streaming==parallel equivalence
# (DESIGN.md contract 5/6, at the JAX level), and loss-decreases sanity for
# every domain's train step.
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import infer, model
from compile.layers import ModelCfg, block_apply, count_params, init_block
from compile.train import make_train_step

CFG_A = ModelCfg(kind="aaren", d_model=16, n_heads=2, n_layers=2, d_mlp=32)
CFG_T = ModelCfg(kind="tf", d_model=16, n_heads=2, n_layers=2, d_mlp=32)


def _key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# block-level


@pytest.mark.parametrize("cfg", [CFG_A, CFG_T], ids=["aaren", "tf"])
def test_block_shapes(cfg):
    p = init_block(_key(0), cfg)
    x = jax.random.normal(_key(1), (3, 10, cfg.d_model))
    mask = jnp.ones((3, 10))
    y = block_apply(p, cfg, x, mask)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.array(y)))


def test_aaren_block_param_overhead_is_d_model():
    """§4.5: Aaren = Transformer + exactly d_model params per block."""
    pa = init_block(_key(0), CFG_A)
    pt = init_block(_key(0), CFG_T)
    assert count_params(pa) - count_params(pt) == CFG_A.d_model


def test_block_causality():
    """Output at position t must not depend on tokens after t."""
    for cfg in (CFG_A, CFG_T):
        p = init_block(_key(2), cfg)
        x = jax.random.normal(_key(3), (1, 12, cfg.d_model))
        mask = jnp.ones((1, 12))
        y1 = block_apply(p, cfg, x, mask)
        x2 = x.at[:, 7:].set(jax.random.normal(_key(4), (1, 5, cfg.d_model)))
        y2 = block_apply(p, cfg, x2, mask)
        np.testing.assert_allclose(y1[:, :7], y2[:, :7], atol=1e-5)
        assert not np.allclose(np.array(y1[:, 7:]), np.array(y2[:, 7:]), atol=1e-3)


# ---------------------------------------------------------------------------
# streaming == parallel (the paper's central claim, contracts 5/6)


def test_aaren_streaming_equals_parallel():
    c, n = 4, 24
    params = model.init_stream(_key(5), CFG_A, c)
    x = jax.random.normal(_key(6), (1, n, c))
    full = model.stream_forward(params, CFG_A, x)[0]  # (n, c)

    a, cc, m = infer.aaren_state_init(CFG_A)
    outs = []
    for t in range(n):
        a, cc, m, y = infer.stream_aaren_step(
            params, CFG_A, a, cc, m, jnp.asarray(t, jnp.int32), x[0, t]
        )
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs), full, atol=1e-4)


def test_aaren_state_is_constant_size():
    """The O(1)-memory claim: state size is independent of #tokens."""
    a, c, m = infer.aaren_state_init(CFG_A)
    n_floats = a.size + c.size + m.size
    assert n_floats == CFG_A.n_layers * CFG_A.n_heads * (CFG_A.d_head + 2)


def test_tf_kv_streaming_equals_parallel():
    c, n, ctx = 4, 24, 32
    params = model.init_stream(_key(7), CFG_T, c)
    x = jax.random.normal(_key(8), (1, n, c))
    full = model.stream_forward(params, CFG_T, x)[0]

    kc, vc = infer.kv_state_init(CFG_T, ctx)
    outs = []
    for t in range(n):
        kc, vc, y = infer.stream_tf_step(
            params, CFG_T, kc, vc, jnp.asarray(t, jnp.int32), x[0, t], ctx
        )
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs), full, atol=1e-4)


def test_kv_bucket_migration_preserves_outputs():
    """Copying a full small cache into the prefix of a larger bucket must
    not change subsequent outputs (the rust session manager's migration)."""
    c, n1, ctx1, ctx2 = 4, 16, 16, 32
    params = model.init_stream(_key(9), CFG_T, c)
    x = jax.random.normal(_key(10), (1, 24, c))

    kc, vc = infer.kv_state_init(CFG_T, ctx1)
    for t in range(n1):
        kc, vc, y_small = infer.stream_tf_step(
            params, CFG_T, kc, vc, jnp.asarray(t, jnp.int32), x[0, t], ctx1
        )
    kc2, vc2 = infer.kv_state_init(CFG_T, ctx2)
    kc2 = kc2.at[:, :, :ctx1].set(kc)
    vc2 = vc2.at[:, :, :ctx1].set(vc)
    outs = []
    for t in range(n1, 24):
        kc2, vc2, y = infer.stream_tf_step(
            params, CFG_T, kc2, vc2, jnp.asarray(t, jnp.int32), x[0, t], ctx2
        )
        outs.append(y)
    full = model.stream_forward(params, CFG_T, x)[0]
    np.testing.assert_allclose(jnp.stack(outs), full[n1:], atol=1e-4)


# ---------------------------------------------------------------------------
# per-domain heads: shapes + train-step-decreases-loss


def _run_steps(loss_fn, params, batch, n_steps=8, lr=1e-2):
    step_fn = make_train_step(loss_fn, lr=lr)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    step = jnp.asarray(0.0)
    losses = []
    for _ in range(n_steps):
        params, m, v, step, loss = step_fn(params, m, v, step, *batch)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("cfg", [CFG_A, CFG_T], ids=["aaren", "tf"])
def test_stream_train_decreases_loss(cfg):
    params = model.init_stream(_key(11), cfg, 4)
    x = jax.random.normal(_key(12), (4, 16, 4))
    losses = _run_steps(lambda p, x: model.stream_loss(p, cfg, x), params, (x,))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("cfg", [CFG_A, CFG_T], ids=["aaren", "tf"])
def test_tsf_shapes_and_training(cfg):
    T = 8
    params = model.init_tsf(_key(13), cfg, 3, T)
    x = jax.random.normal(_key(14), (4, 12, 3))
    y = jax.random.normal(_key(15), (4, T, 3))
    pred = model.tsf_forward(params, cfg, T, x)
    assert pred.shape == (4, T, 3)
    sse, sae = model.tsf_eval(params, cfg, T, x, y)
    assert sse.shape == () and sae.shape == ()
    losses = _run_steps(
        lambda p, x, y: model.tsf_loss(p, cfg, T, x, y), params, (x, y)
    )
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("cfg", [CFG_A, CFG_T], ids=["aaren", "tf"])
def test_tsc_shapes_and_training(cfg):
    ncls = 5
    params = model.init_tsc(_key(16), cfg, 3, ncls)
    x = jax.random.normal(_key(17), (6, 10, 3))
    labels = jnp.asarray([0, 1, 2, 3, 4, 0], jnp.int32)
    logits = model.tsc_logits(params, cfg, x)
    assert logits.shape == (6, ncls)
    correct, nll = model.tsc_eval(params, cfg, x, labels)
    assert 0 <= float(correct) <= 6
    losses = _run_steps(
        lambda p, x, l: model.tsc_loss(p, cfg, x, l), params, (x, labels)
    )
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("cfg", [CFG_A, CFG_T], ids=["aaren", "tf"])
def test_ef_shapes_and_training(cfg):
    marks, mix = 4, 3
    params = model.init_ef(_key(18), cfg, marks, mix)
    dt = jax.random.uniform(_key(19), (4, 12), minval=0.05, maxval=1.0)
    times = jnp.cumsum(dt, axis=1)
    mk = jax.random.randint(_key(20), (4, 12), 0, marks)
    nll_sum, sq_sum, correct, n = model.ef_eval(params, cfg, mix, times, mk)
    assert float(n) == 4 * 11
    assert np.isfinite(float(nll_sum)) and float(sq_sum) >= 0
    losses = _run_steps(
        lambda p, t, m: model.ef_loss(p, cfg, mix, t, m), params, (times, mk),
        lr=3e-3,
    )
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("cfg", [CFG_A, CFG_T], ids=["aaren", "tf"])
def test_rl_shapes_and_training(cfg):
    T, S, A = 6, 5, 3
    params = model.init_rl(_key(21), cfg, S, A, 64)
    rtg = jax.random.normal(_key(22), (4, T, 1))
    states = jax.random.normal(_key(23), (4, T, S))
    actions = jnp.tanh(jax.random.normal(_key(24), (4, T, A)))
    ts = jnp.tile(jnp.arange(T, dtype=jnp.int32), (4, 1))
    mask = jnp.ones((4, T))
    pred = model.rl_forward(params, cfg, rtg, states, actions, ts, mask)
    assert pred.shape == (4, T, A)
    assert np.all(np.abs(np.array(pred)) <= 1.0)
    act = model.rl_act(params, cfg, rtg[:1], states[:1], actions[:1], ts[:1], mask[:1])
    assert act.shape == (1, A)
    losses = _run_steps(
        lambda p, *b: model.rl_loss(p, cfg, *b),
        params, (rtg, states, actions, ts, mask),
    )
    assert losses[-1] < losses[0]


def test_rl_masked_positions_do_not_affect_live_predictions():
    """Left-padding contract for online rollouts: junk in masked slots must
    not change the action predicted at live slots."""
    cfg = CFG_A
    T, S, A = 8, 5, 3
    params = model.init_rl(_key(25), cfg, S, A, 64)
    rtg = jax.random.normal(_key(26), (1, T, 1))
    states = jax.random.normal(_key(27), (1, T, S))
    actions = jnp.tanh(jax.random.normal(_key(28), (1, T, A)))
    ts = jnp.tile(jnp.arange(T, dtype=jnp.int32), (1, 1))
    mask = jnp.concatenate([jnp.zeros((1, 3)), jnp.ones((1, 5))], axis=1)
    a1 = model.rl_act(params, cfg, rtg, states, actions, ts, mask)
    # scramble the masked (padding) slots
    rtg2 = rtg.at[:, :3].set(99.0)
    states2 = states.at[:, :3].set(-7.0)
    actions2 = actions.at[:, :3].set(0.5)
    a2 = model.rl_act(params, cfg, rtg2, states2, actions2, ts, mask)
    np.testing.assert_allclose(a1, a2, atol=1e-5)


def test_lognormal_mixture_nll_matches_closed_form():
    """Single-component mixture == closed-form log-normal NLL."""
    from compile.model import _lognormal_mixture_nll

    head = jnp.asarray([0.0, 0.3, -0.2])  # w_logit, mu, log_sig (K=1)
    dt = jnp.asarray(0.7)
    nll, exp_dt = _lognormal_mixture_nll(head, dt, 1)
    mu, sig = 0.3, np.exp(-0.2)
    want = -(
        -0.5 * ((np.log(0.7) - mu) / sig) ** 2
        - np.log(sig)
        - 0.5 * np.log(2 * np.pi)
        - np.log(0.7)
    )
    np.testing.assert_allclose(float(nll), want, rtol=1e-5)
    # point prediction is the mixture of component medians exp(mu)
    # (robust reporting choice — see model.py::_lognormal_mixture_nll)
    np.testing.assert_allclose(float(exp_dt), np.exp(mu), rtol=1e-5)
