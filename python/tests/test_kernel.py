# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal
# for Layer 1 (DESIGN.md §6, contracts 1 and 2).
#
# hypothesis sweeps shapes and value regimes; fixed-seed tests pin the
# exact configurations the AOT artifacts use.
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.causal_attention import causal_attention
from compile.kernels.scan_attention import recurrent_step, scan_attention

ATOL = 2e-5


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _mask(key, bh, n, p_live=0.8):
    u = jax.random.uniform(jax.random.PRNGKey(key), (bh, n))
    return (u < p_live).astype(jnp.float32)


# ---------------------------------------------------------------------------
# contract 1: pallas scan kernel == naive oracle


@pytest.mark.parametrize("n", [1, 2, 3, 7, 8, 33, 64, 100, 128])
def test_scan_kernel_matches_naive_all_lengths(n):
    bh, d = 3, 16
    q, k, v = _rand(0, bh, d), _rand(1, bh, n, d), _rand(2, bh, n, d)
    mask = jnp.ones((bh, n), jnp.float32)
    out = scan_attention(q, k, v, mask)
    want = ref.multihead_prefix_attention(q, k, v, mask)
    np.testing.assert_allclose(out, want, atol=ATOL)


@pytest.mark.parametrize("d", [1, 2, 8, 16, 32, 64])
def test_scan_kernel_matches_naive_all_widths(d):
    bh, n = 2, 24
    q, k, v = _rand(3, bh, d), _rand(4, bh, n, d), _rand(5, bh, n, d)
    mask = jnp.ones((bh, n), jnp.float32)
    np.testing.assert_allclose(
        scan_attention(q, k, v, mask),
        ref.multihead_prefix_attention(q, k, v, mask),
        atol=ATOL,
    )


def test_scan_kernel_with_random_mask():
    bh, n, d = 4, 40, 8
    q, k, v = _rand(6, bh, d), _rand(7, bh, n, d), _rand(8, bh, n, d)
    mask = _mask(9, bh, n, p_live=0.6)
    np.testing.assert_allclose(
        scan_attention(q, k, v, mask),
        ref.multihead_prefix_attention(q, k, v, mask),
        atol=ATOL,
    )


def test_scan_kernel_fully_masked_prefix_is_finite():
    """Left-padded sequences (RL rollouts) start with masked tokens; the
    kernel must stay finite there (DESIGN.md: MASK_FILL, not -inf)."""
    bh, n, d = 2, 16, 8
    q, k, v = _rand(10, bh, d), _rand(11, bh, n, d), _rand(12, bh, n, d)
    mask = jnp.concatenate(
        [jnp.zeros((bh, 8)), jnp.ones((bh, 8))], axis=1
    ).astype(jnp.float32)
    out = scan_attention(q, k, v, mask)
    assert np.all(np.isfinite(np.array(out)))
    np.testing.assert_allclose(
        out, ref.multihead_prefix_attention(q, k, v, mask), atol=ATOL
    )


def test_scan_kernel_extreme_scores_stable():
    """The cumulative-max trick (§3.1 footnote 2): scores of magnitude ~80
    would overflow exp() without it."""
    bh, n, d = 2, 32, 4
    q = 10.0 * _rand(13, bh, d)
    k = 10.0 * _rand(14, bh, n, d)
    v = _rand(15, bh, n, d)
    mask = jnp.ones((bh, n), jnp.float32)
    out = scan_attention(q, k, v, mask)
    assert np.all(np.isfinite(np.array(out)))
    np.testing.assert_allclose(
        out, ref.multihead_prefix_attention(q, k, v, mask), atol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 96),
    d=st.sampled_from([4, 8, 16]),
    bh=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 5.0]),
)
def test_scan_kernel_hypothesis(n, d, bh, seed, scale):
    q = scale * _rand(seed, bh, d)
    k = scale * _rand(seed + 1, bh, n, d)
    v = _rand(seed + 2, bh, n, d)
    mask = _mask(seed + 3, bh, n)
    np.testing.assert_allclose(
        scan_attention(q, k, v, mask),
        ref.multihead_prefix_attention(q, k, v, mask),
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# contract 2: the three reference formulations agree (paper §3.1/§3.2)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_recurrent_equals_naive(n, seed):
    d = 8
    q, k, v = _rand(seed, d), _rand(seed + 1, n, d), _rand(seed + 2, n, d)
    np.testing.assert_allclose(
        ref.recurrent_prefix_attention(q, k, v),
        ref.naive_prefix_attention(q, k, v),
        atol=ATOL,
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_assoc_scan_equals_naive(n, seed):
    d = 8
    q, k, v = _rand(seed, d), _rand(seed + 1, n, d), _rand(seed + 2, n, d)
    np.testing.assert_allclose(
        ref.assoc_scan_prefix_attention(q, k, v),
        ref.naive_prefix_attention(q, k, v),
        atol=ATOL,
    )


def test_combine_operator_associative():
    """Appendix B: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) including extreme m values."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        tup = []
        for _i in range(3):
            m = jnp.asarray(rng.uniform(-85, 85), jnp.float32)
            u = jnp.asarray(rng.uniform(0.1, 3.0), jnp.float32)
            w = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
            tup.append((m, u, w))
        a, b, c = tup
        left = ref.combine(ref.combine(a, b), c)
        right = ref.combine(a, ref.combine(b, c))
        for lx, rx in zip(left, right):
            np.testing.assert_allclose(lx, rx, rtol=1e-5, atol=1e-5)


def test_combine_identity_element():
    ident = (
        jnp.asarray(ref.MASK_FILL, jnp.float32),
        jnp.asarray(0.0, jnp.float32),
        jnp.zeros((4,), jnp.float32),
    )
    x = (
        jnp.asarray(1.3, jnp.float32),
        jnp.asarray(2.0, jnp.float32),
        jnp.arange(4.0, dtype=jnp.float32),
    )
    for got, want in zip(ref.combine(ident, x), x):
        np.testing.assert_allclose(got, want, atol=1e-6)
    for got, want in zip(ref.combine(x, ident), x):
        np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# the O(1) recurrent-step kernel streams to the same answer


@pytest.mark.parametrize("n", [1, 5, 32])
def test_recurrent_step_kernel_streams_to_naive(n):
    bh, d = 3, 8
    q, k, v = _rand(20, bh, d), _rand(21, bh, n, d), _rand(22, bh, n, d)
    a = jnp.zeros((bh, d))
    c = jnp.zeros((bh, 1))
    m = jnp.full((bh, 1), ref.MASK_FILL)
    outs = []
    for t in range(n):
        a, c, m, o = recurrent_step(q, k[:, t], v[:, t], a, c, m)
        outs.append(o)
    got = jnp.stack(outs, axis=1)  # (bh, n, d)
    want = ref.multihead_prefix_attention(
        q, k, v, jnp.ones((bh, n), jnp.float32)
    )
    np.testing.assert_allclose(got, want, atol=ATOL)


# ---------------------------------------------------------------------------
# baseline kernel == baseline oracle


@pytest.mark.parametrize("n", [1, 2, 17, 64])
def test_causal_kernel_matches_ref(n):
    bh, d = 3, 16
    q, k, v = _rand(30, bh, n, d), _rand(31, bh, n, d), _rand(32, bh, n, d)
    mask = _mask(33, bh, n)
    np.testing.assert_allclose(
        causal_attention(q, k, v, mask),
        ref.multihead_causal_self_attention(q, k, v, mask),
        atol=ATOL,
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_causal_kernel_hypothesis(n, seed):
    bh, d = 2, 8
    q, k, v = _rand(seed, bh, n, d), _rand(seed + 1, bh, n, d), _rand(seed + 2, bh, n, d)
    mask = _mask(seed + 3, bh, n)
    np.testing.assert_allclose(
        causal_attention(q, k, v, mask),
        ref.multihead_causal_self_attention(q, k, v, mask),
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# gradients: custom_vjp backward equals the reference's autodiff


def test_scan_attention_gradients_match_reference():
    bh, n, d = 2, 16, 8
    q, k, v = _rand(40, bh, d), _rand(41, bh, n, d), _rand(42, bh, n, d)
    mask = jnp.ones((bh, n), jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(scan_attention(q, k, v, mask) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.multihead_prefix_attention(q, k, v, mask) ** 2)

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gk, gr in zip(g_kernel, g_ref):
        np.testing.assert_allclose(gk, gr, atol=1e-4)


def test_causal_attention_gradients_match_reference():
    bh, n, d = 2, 12, 8
    q, k, v = _rand(43, bh, n, d), _rand(44, bh, n, d), _rand(45, bh, n, d)
    mask = jnp.ones((bh, n), jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(causal_attention(q, k, v, mask) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.multihead_causal_self_attention(q, k, v, mask) ** 2)

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gk, gr in zip(g_kernel, g_ref):
        np.testing.assert_allclose(gk, gr, atol=1e-4)
