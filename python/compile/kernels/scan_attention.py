# L1 Pallas kernel: attention as a many-to-many RNN (paper §3.2).
#
# Computes all prefix outputs { o_k = Attention(q, x_{1:k}) }_{k=1..N} in a
# single kernel via a Hillis–Steele parallel prefix scan (Algorithm 1 in
# the paper) over the associative operator ⊕ acting on (m, u, w) tuples.
#
# TPU mapping (DESIGN.md §Hardware-Adaptation):
#   * grid = (B·H,): one program per (batch, head); each program's k/v/o
#     block is an (N, d) VMEM tile selected by BlockSpec.
#   * scores s = k @ q is a single (N,d)×(d,1) contraction → MXU.
#   * the scan is ceil(log2 N) full-width shift-and-combine sweeps over
#     VMEM-resident (N,) / (N,d) arrays → VPU vector ops, not a sequential
#     per-token loop. This is the TPU analogue of the paper's GPU scan.
#   * VMEM budget per program: (3·N·d + 3·N) f32 ≈ 0.79 MiB at N=1024,
#     d=64 — comfortably under the ~16 MiB/core budget (see DESIGN.md
#     §Perf for the full table).
#
# interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
# custom-calls, so the kernel is lowered to plain HLO ops; correctness is
# validated against kernels/ref.py, and TPU performance is estimated
# analytically from the BlockSpec schedule.
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MASK_FILL


def _shift_down(x: jax.Array, off: int, fill: float) -> jax.Array:
    """y[j] = x[j - off] for j >= off else `fill` (static offset).

    Implemented as lax.pad(front=off, back=-off) rather than
    concatenate([full, slice]): the concatenate formulation is miscompiled
    by the xla_extension 0.5.1 CPU backend for N >= 16 (wrong prefix
    outputs; bisected in EXPERIMENTS.md §Gotchas). lax.pad round-trips
    correctly and is also the more natural windowing op on TPU.
    """
    cfg = [(off, -off, 0)] + [(0, 0, 0)] * (x.ndim - 1)
    return jax.lax.pad(x, jnp.asarray(fill, x.dtype), cfg)


def _scan_attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, seq_len: int):
    """One (batch, head) program: prefix-scan attention over an (N, d) tile."""
    q = q_ref[0, :]  # (d,)
    k = k_ref[0, :, :]  # (N, d)
    v = v_ref[0, :, :]  # (N, d)
    mask = mask_ref[0, :]  # (N,)

    d = q.shape[-1]
    # s_i = <q, k_i>/sqrt(d): one (N,d)x(d,) contraction -> MXU on TPU.
    s = jnp.dot(k, q) * (1.0 / math.sqrt(d))
    s = jnp.where(mask > 0, s, jnp.asarray(MASK_FILL, dtype=s.dtype))

    # Leaf tuples (m, u, w) = (s_i, 1, v_i); identity = (MASK_FILL, 0, 0).
    m = s
    u = jnp.ones_like(s)
    w = v

    # Hillis–Steele: ceil(log2 N) full-width sweeps. Each sweep combines
    # element j with element j - 2^i via the paper's ⊕ (Appendix B).
    n_sweeps = max(1, math.ceil(math.log2(seq_len))) if seq_len > 1 else 0
    for i in range(n_sweeps):
        off = 1 << i
        if off >= seq_len:
            break
        m_p = _shift_down(m, off, MASK_FILL)
        u_p = _shift_down(u, off, 0.0)
        w_p = _shift_down(w, off, 0.0)
        m_new = jnp.maximum(m, m_p)
        ea = jnp.exp(m_p - m_new)  # weight of the earlier (A) segment
        eb = jnp.exp(m - m_new)  # weight of the current (B) segment
        u = u_p * ea + u * eb
        w = w_p * ea[:, None] + w * eb[:, None]
        m = m_new

    o_ref[0, :, :] = w / u[:, None]


def _scan_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array
) -> jax.Array:
    bh, n, d = k.shape
    kernel = functools.partial(_scan_attention_kernel, seq_len=n)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), k.dtype),
        interpret=True,
    )(q, k, v, mask)


@jax.custom_vjp
def scan_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array
) -> jax.Array:
    """Many-to-many attention for a batch of heads (paper §3.2).

    q: (BH, d) — one learned query per (batch, head);
    k, v: (BH, N, d); mask: (BH, N) in {0,1}.
    Returns o: (BH, N, d) with o[:, t] = Attention(q, x_{1:t}).

    Forward is the Pallas prefix-scan kernel; backward is the VJP of the
    mathematically identical `lax.associative_scan` reference (Pallas
    interpret-mode calls do not support reverse-mode AD). Both paths are
    cross-checked in python/tests/.
    """
    return _scan_attention_pallas(q, k, v, mask)


def _scan_attention_ref(q, k, v, mask):
    from . import ref  # local import to avoid a cycle at module load

    return jax.vmap(ref.assoc_scan_prefix_attention)(q, k, v, mask)


def _scan_attention_fwd(q, k, v, mask):
    return _scan_attention_pallas(q, k, v, mask), (q, k, v, mask)


def _scan_attention_bwd(res, g):
    q, k, v, mask = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _scan_attention_ref(q_, k_, v_, mask), q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, jnp.zeros_like(mask)


scan_attention.defvjp(_scan_attention_fwd, _scan_attention_bwd)


def _recurrent_step_kernel(q_ref, k_ref, v_ref, a_ref, c_ref, m_ref, o_ref):
    """Single-token RNN cell (paper §3.1, Figure 2) as a Pallas kernel.

    In/out aliasing is handled by the caller; this kernel computes the
    (a, c, m) update for one new token and emits o = a'/c'. Used by the
    streaming infer path's unit tests; the AOT streaming step lowers the
    same math from model-level JAX (infer.py).
    """
    q = q_ref[0, :]
    k = k_ref[0, :]
    v = v_ref[0, :]
    a = a_ref[0, :]
    c = c_ref[0, 0]
    m = m_ref[0, 0]
    d = q.shape[-1]
    s = jnp.dot(k, q) * (1.0 / math.sqrt(d))
    m_new = jnp.maximum(m, s)
    ea = jnp.exp(m - m_new)
    eb = jnp.exp(s - m_new)
    a_new = a * ea + v * eb
    c_new = c * ea + eb
    o_ref[0, : d] = a_new
    o_ref[0, d] = c_new
    o_ref[0, d + 1] = m_new


def recurrent_step(
    q: jax.Array, k: jax.Array, v: jax.Array, a: jax.Array, c: jax.Array, m: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """O(1)-memory attention update for a batch of heads.

    q/k/v/a: (BH, d); c/m: (BH, 1). Returns (a', c', m', o) where
    o = a'/c' is the refreshed attention output after absorbing token k/v.
    """
    bh, d = q.shape
    packed = pl.pallas_call(
        _recurrent_step_kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d + 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d + 2), q.dtype),
        interpret=True,
    )(q, k, v, a, c, m)
    a_new = packed[:, :d]
    c_new = packed[:, d : d + 1]
    m_new = packed[:, d + 1 :]
    return a_new, c_new, m_new, a_new / c_new
