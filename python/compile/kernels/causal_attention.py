# L1 Pallas kernel: standard causal self-attention — the Transformer
# baseline the paper compares Aaren against (Vaswani et al., 2017).
#
# One program per (batch, head); the (N, N) score tile lives in VMEM.
# Numerically-stable masked softmax (row max subtraction) matches the
# paper's formulation Attention(Q, K, V) = softmax(QK^T)V with a causal
# mask and the usual 1/sqrt(d) scale.
#
# VMEM per program: (3·N·d + N²) f32 — quadratic in N, which is exactly
# the cost profile the paper attributes to Transformers; contrast with
# scan_attention.py's linear (3·N·d + 3·N).
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MASK_FILL


def _causal_attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, seq_len: int):
    q = q_ref[0, :, :]  # (N, d)
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    mask = mask_ref[0, :]  # (N,) over keys

    d = q.shape[-1]
    s = jnp.dot(q, k.T) * (1.0 / math.sqrt(d))  # (N, N) -> MXU
    rows = jax.lax.broadcasted_iota(jnp.int32, (seq_len, seq_len), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (seq_len, seq_len), 1)
    live = jnp.logical_and(cols <= rows, mask[None, :] > 0)
    # Keep the diagonal live even for masked tokens: guarantees a nonzero
    # softmax denominator on fully-masked prefixes (see kernels/ref.py).
    live = jnp.logical_or(live, rows == cols)
    s = jnp.where(live, s, jnp.asarray(MASK_FILL, dtype=s.dtype))
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s) * live
    o_ref[0, :, :] = jnp.dot(w, v) / jnp.sum(w, axis=-1, keepdims=True)


def _causal_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array
) -> jax.Array:
    bh, n, d = q.shape
    kernel = functools.partial(_causal_attention_kernel, seq_len=n)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), q.dtype),
        interpret=True,
    )(q, k, v, mask)


@jax.custom_vjp
def causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array
) -> jax.Array:
    """Causal self-attention for a batch of heads.

    q, k, v: (BH, N, d); mask: (BH, N) over keys. Returns (BH, N, d).
    Forward is the Pallas kernel; backward is the VJP of the identical
    jnp reference (interpret-mode Pallas has no reverse-mode AD).
    """
    return _causal_attention_pallas(q, k, v, mask)


def _causal_attention_ref(q, k, v, mask):
    from . import ref

    return ref.multihead_causal_self_attention(q, k, v, mask)


def _causal_attention_fwd(q, k, v, mask):
    return _causal_attention_pallas(q, k, v, mask), (q, k, v, mask)


def _causal_attention_bwd(res, g):
    q, k, v, mask = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _causal_attention_ref(q_, k_, v_, mask), q, k, v
    )
    dq, dk, dv = vjp(g)
    return dq, dk, dv, jnp.zeros_like(mask)


causal_attention.defvjp(_causal_attention_fwd, _causal_attention_bwd)
