# Pure-jnp correctness oracles for the Aaren attention kernels.
#
# Three independent formulations of the paper's many-to-many attention
#   { o_k = Attention(q, x_{1:k}) }_{k=1..N}
# are implemented here:
#
#   1. `naive_prefix_attention`   — the textbook O(N^2) masked softmax
#      (conventional attention with a causal mask over a broadcast query).
#   2. `recurrent_prefix_attention` — the paper's Section 3.1 RNN cell,
#      iterating the numerically-stable (a_k, c_k, m_k) recurrence with
#      `lax.scan`.
#   3. `assoc_scan_prefix_attention` — the paper's Section 3.2 parallel
#      prefix scan with the associative operator ⊕ on (m, u, w) tuples,
#      via `lax.associative_scan` (Blelloch-style, O(N) work).
#
# All three must agree to tight tolerance; the Pallas kernel
# (`scan_attention.py`) is validated against them in python/tests/.
#
# Conventions: q is a single query vector per (batch, head); k, v carry the
# full sequence. `mask` is 1.0 for live tokens and 0.0 for padding; masked
# scores are filled with MASK_FILL (finite, so no NaNs propagate — see
# DESIGN.md §6).
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Finite "minus infinity": exp(MASK_FILL - m) underflows to exactly 0.0 in
# f32 while keeping every intermediate finite (a true -inf would produce
# NaN via `-inf - -inf` inside the scan combine on fully-masked prefixes).
MASK_FILL = -1e9


def scores(q: jax.Array, k: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """s_i = <q, k_i> / sqrt(d), masked positions filled with MASK_FILL.

    q: (d,), k: (N, d), mask: (N,) in {0,1} -> returns (N,).
    """
    d = q.shape[-1]
    s = k @ q / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    if mask is not None:
        s = jnp.where(mask > 0, s, jnp.asarray(MASK_FILL, dtype=s.dtype))
    return s


def naive_prefix_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """O(N^2) oracle: o_k = softmax(s_{1:k}) @ v_{1:k} for every prefix k.

    q: (d,), k: (N, d), v: (N, dv), mask: (N,) -> (N, dv).
    """
    n = k.shape[0]
    s = scores(q, k, mask)  # (N,)
    # causal[i, j] = 1 if j <= i (query position i sees context 1..i)
    causal = jnp.tril(jnp.ones((n, n), dtype=bool))
    smat = jnp.where(causal, s[None, :], MASK_FILL)  # (N, N)
    smat = smat - jnp.max(smat, axis=-1, keepdims=True)
    # Zero non-causal weights explicitly: on a fully-masked prefix the row
    # max equals MASK_FILL and exp(0)=1 would otherwise leak weight to
    # future positions. With the explicit causal product this oracle matches
    # the scan/recurrent semantics exactly (mean over the masked prefix).
    w = jnp.exp(smat) * causal
    return (w @ v) / jnp.sum(w, axis=-1, keepdims=True)


def recurrent_prefix_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Section 3.1 RNN cell, iterated with lax.scan — O(1) state per step.

    State (a, c, m):
        m_k = max(m_{k-1}, s_k)
        a_k = a_{k-1} exp(m_{k-1} - m_k) + v_k exp(s_k - m_k)
        c_k = c_{k-1} exp(m_{k-1} - m_k) +     exp(s_k - m_k)
        o_k = a_k / c_k
    """
    s = scores(q, k, mask)
    dv = v.shape[-1]

    def cell(carry, inp):
        a, c, m = carry
        s_k, v_k = inp
        m_new = jnp.maximum(m, s_k)
        ea = jnp.exp(m - m_new)
        eb = jnp.exp(s_k - m_new)
        a_new = a * ea + v_k * eb
        c_new = c * ea + eb
        return (a_new, c_new, m_new), a_new / c_new

    init = (
        jnp.zeros((dv,), dtype=v.dtype),
        jnp.zeros((), dtype=v.dtype),
        jnp.asarray(MASK_FILL, dtype=v.dtype),
    )
    _, outs = lax.scan(cell, init, (s, v))
    return outs


def combine(ta, tb):
    """The paper's associative operator ⊕ on (m, u, w) tuples (Appendix B).

    (m_A, u_A, w_A) ⊕ (m_B, u_B, w_B) = (m_AB, u_AB, w_AB) with
        m_AB = max(m_A, m_B)
        u_AB = u_A exp(m_A - m_AB) + u_B exp(m_B - m_AB)
        w_AB = w_A exp(m_A - m_AB) + w_B exp(m_B - m_AB)
    Identity element: (MASK_FILL, 0, 0).
    """
    m_a, u_a, w_a = ta
    m_b, u_b, w_b = tb
    m = jnp.maximum(m_a, m_b)
    ea = jnp.exp(m_a - m)
    eb = jnp.exp(m_b - m)
    u = u_a * ea + u_b * eb
    w = w_a * ea[..., None] + w_b * eb[..., None]
    return m, u, w


def assoc_scan_prefix_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Section 3.2: many-to-many attention via lax.associative_scan over ⊕."""
    s = scores(q, k, mask)
    leaves = (s, jnp.ones_like(s), v)  # (m_{i}, u_{i}, w_{i}) = (s_i, 1, v_i)
    m, u, w = lax.associative_scan(combine, leaves)
    return w / u[..., None]


def multihead_prefix_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Batched/multi-head wrapper over the naive oracle.

    q: (BH, d), k/v: (BH, N, d), mask: (BH, N) -> (BH, N, d).
    """
    if mask is None:
        mask = jnp.ones(k.shape[:2], dtype=k.dtype)
    return jax.vmap(naive_prefix_attention)(q, k, v, mask)


def naive_causal_self_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Baseline oracle: standard causal self-attention for one head.

    q/k/v: (N, d); mask: (N,) over *keys* -> (N, d).
    """
    n, d = q.shape
    s = q @ k.T / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))  # (N, N)
    live = jnp.tril(jnp.ones((n, n), dtype=bool))
    if mask is not None:
        live = jnp.logical_and(live, mask[None, :] > 0)
    # Keep the diagonal live even when the token itself is masked so every
    # row has at least one weight (masked rows are dropped by the loss; a
    # zero denominator would instead propagate NaNs into live rows'
    # gradients). The Pallas kernel implements the identical rule.
    live = jnp.logical_or(live, jnp.eye(n, dtype=bool))
    s = jnp.where(live, s, MASK_FILL)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s) * live
    return (w @ v) / jnp.sum(w, axis=-1, keepdims=True)


def multihead_causal_self_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Batched baseline oracle. q/k/v: (BH, N, d), mask: (BH, N)."""
    if mask is None:
        mask = jnp.ones(k.shape[:2], dtype=k.dtype)
    return jax.vmap(naive_causal_self_attention)(q, k, v, mask)
