# L2 streaming inference steps — the paper's headline efficiency claim
# made executable (§3.3, Figure 5).
#
#   Aaren step      — O(1) memory and compute per new token: the only
#                     state is (a, c, m) per (layer, head), i.e.
#                     L·H·(d_head + 2) floats, independent of sequence
#                     length.
#   Transformer step — KV-cache baseline: state is (K, V) caches of shape
#                     (L, H, ctx, d_head) plus a position counter. Memory
#                     grows linearly with context; per-token compute grows
#                     with the bucket size, so cumulative time is
#                     quadratic — the Figure-5 comparison.
#
# Both steps are lowered to standalone HLO modules; the rust session
# manager owns the state buffers and feeds each step's state outputs back
# into the next step's state inputs.
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ref import MASK_FILL
from .layers import ModelCfg, layer_norm, linear, mlp_apply, sinusoidal_at


# ---------------------------------------------------------------------------
# Aaren: constant-memory recurrent update (paper §3.1 cell, stacked §3.3)


def aaren_state_init(cfg: ModelCfg):
    """Zero state: a=(L,H,dh) zeros, c=(L,H) zeros, m=(L,H) MASK_FILL."""
    sl = (cfg.n_layers, cfg.n_heads, cfg.d_head)
    return (
        jnp.zeros(sl, jnp.float32),
        jnp.zeros(sl[:2], jnp.float32),
        jnp.full(sl[:2], MASK_FILL, jnp.float32),
    )


def aaren_block_step(blk: dict, cfg: ModelCfg, a, c, m, x):
    """One layer's recurrent update for one token. x: (d,). Returns
    (a', c', m', y) with y the block output for this token."""
    h = layer_norm(blk["ln1"], x)
    k = linear(blk["wk"], h).reshape(cfg.n_heads, cfg.d_head)
    v = linear(blk["wv"], h).reshape(cfg.n_heads, cfg.d_head)
    q = linear(blk["wq"], blk["q"]).reshape(cfg.n_heads, cfg.d_head)
    s = jnp.sum(q * k, axis=-1) / jnp.sqrt(
        jnp.asarray(cfg.d_head, jnp.float32)
    )  # (H,)
    m_new = jnp.maximum(m, s)
    ea = jnp.exp(m - m_new)
    eb = jnp.exp(s - m_new)
    a_new = a * ea[:, None] + v * eb[:, None]
    c_new = c * ea + eb
    o = (a_new / c_new[:, None]).reshape(cfg.d_model)
    x = x + linear(blk["wo"], o)
    x = x + mlp_apply(blk["mlp"], layer_norm(blk["ln2"], x))
    return a_new, c_new, m_new, x


def stream_aaren_step(params, cfg: ModelCfg, a, c, m, t, x_t):
    """Full-model O(1) update. x_t: (C,), t: i32 scalar position.
    Returns (a', c', m', y) with y: (C,) the next-value prediction."""
    h = linear(params["embed"], x_t) + sinusoidal_at(t, cfg.d_model)
    a_out, c_out, m_out = [], [], []
    for i, blk in enumerate(params["backbone"]["blocks"]):
        a_i, c_i, m_i, h = aaren_block_step(blk, cfg, a[i], c[i], m[i], h)
        a_out.append(a_i)
        c_out.append(c_i)
        m_out.append(m_i)
    h = layer_norm(params["backbone"]["ln_f"], h)
    y = linear(params["head"], h)
    return jnp.stack(a_out), jnp.stack(c_out), jnp.stack(m_out), y


# ---------------------------------------------------------------------------
# Transformer: KV-cache update (the paper's comparison baseline, §4.5)


def kv_state_init(cfg: ModelCfg, ctx: int):
    shape = (cfg.n_layers, cfg.n_heads, ctx, cfg.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def tf_block_step(blk: dict, cfg: ModelCfg, k_cache, v_cache, t, x, ctx: int):
    """One layer's KV-cache update. k_cache/v_cache: (H, ctx, dh);
    t: i32 current position (< ctx). Returns (k', v', y)."""
    h = layer_norm(blk["ln1"], x)
    q = linear(blk["wq"], h).reshape(cfg.n_heads, cfg.d_head)
    k = linear(blk["wk"], h).reshape(cfg.n_heads, cfg.d_head)
    v = linear(blk["wv"], h).reshape(cfg.n_heads, cfg.d_head)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k[:, None, :], (0, t, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v[:, None, :], (0, t, 0))
    s = jnp.einsum("hd,hnd->hn", q, k_cache) / jnp.sqrt(
        jnp.asarray(cfg.d_head, jnp.float32)
    )
    live = jnp.arange(ctx)[None, :] <= t  # (1, ctx)
    s = jnp.where(live, s, MASK_FILL)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s) * live
    o = jnp.einsum("hn,hnd->hd", w, v_cache) / jnp.sum(w, axis=-1, keepdims=True)
    x = x + linear(blk["wo"], o.reshape(cfg.d_model))
    x = x + mlp_apply(blk["mlp"], layer_norm(blk["ln2"], x))
    return k_cache, v_cache, x


def stream_tf_step(params, cfg: ModelCfg, k_cache, v_cache, t, x_t, ctx: int):
    """KV-cache full-model step for a fixed context bucket `ctx`.
    k_cache/v_cache: (L, H, ctx, dh). Returns (k', v', y)."""
    h = linear(params["embed"], x_t) + sinusoidal_at(t, cfg.d_model)
    k_out, v_out = [], []
    for i, blk in enumerate(params["backbone"]["blocks"]):
        k_i, v_i, h = tf_block_step(blk, cfg, k_cache[i], v_cache[i], t, h, ctx)
        k_out.append(k_i)
        v_out.append(v_i)
    h = layer_norm(params["backbone"]["ln_f"], h)
    y = linear(params["head"], h)
    return jnp.stack(k_out), jnp.stack(v_out), y
