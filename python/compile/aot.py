# AOT exporter: lowers every model variant ONCE to HLO *text* plus a JSON
# manifest and an initial-parameter binary, then never runs again (the
# Makefile short-circuits when inputs are unchanged). Python is never on
# the request path.
#
# Interchange format is HLO text, NOT a serialized HloModuleProto: jax
# >= 0.5 emits protos with 64-bit instruction ids which xla_extension
# 0.5.1 (the version behind the published `xla` 0.1.6 crate) rejects
# (`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
# cleanly. See /opt/xla-example/README.md.
#
# Per artifact we write:
#   artifacts/<name>.hlo.txt       — the lowered module
#   artifacts/<name>.manifest.json — ordered argument/output specs (role,
#                                    shape, dtype) so the rust runtime is
#                                    fully generic over model variants
#   artifacts/<params_key>.params.bin
#                                  — f32 little-endian initial parameters,
#                                    concatenated in flatten order; shared
#                                    between the train/eval/fwd/step
#                                    artifacts of one model
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import infer, model
from .layers import ModelCfg, count_params
from .train import make_train_step

# ---------------------------------------------------------------------------
# shared dimension presets (mirrored in rust via manifest meta)

STREAM = dict(channels=8, seq=64, batch=8, lr=1e-3)
STREAM_CFG = dict(d_model=64, n_heads=4, n_layers=2, d_mlp=128)
FIG5_BUCKETS = [32, 64, 128, 256, 512]

TSF = dict(channels=7, lookback=96, batch=16, lr=1e-3)
TSF_HORIZONS = [96, 192, 336, 720]
SMALL_CFG = dict(d_model=32, n_heads=2, n_layers=2, d_mlp=64)

TSC = dict(channels=8, seq=96, classes=16, batch=16, lr=1e-3)
EF = dict(seq=64, marks=16, mix=3, batch=16, lr=5e-4)
RL = dict(ctx=20, state_dim=12, act_dim=6, max_t=512, batch=16, lr=3e-4)
RL_CFG = dict(d_model=64, n_heads=4, n_layers=2, d_mlp=128)

# paper-scale config for the §4.5 parameter-count analysis (manifest only)
PARAMCOUNT_CFG = dict(d_model=512, n_heads=4, n_layers=4, d_mlp=2048)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    return {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}[np.dtype(dt)]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _param_entries(params, role: str):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [
        {
            "name": f"{role}:{_path_str(path)}",
            "role": role,
            "shape": list(leaf.shape),
            "dtype": _dtype_str(leaf.dtype),
        }
        for path, leaf in flat
    ]


def _spec_of(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


class Exporter:
    def __init__(self, outdir: str, only: str | None):
        self.outdir = outdir
        self.only = only
        self.written_params: set[str] = set()
        os.makedirs(outdir, exist_ok=True)

    def _skip(self, name: str) -> bool:
        return self.only is not None and self.only not in name

    def write_params(self, params_key: str, params) -> None:
        if params_key in self.written_params:
            return
        self.written_params.add(params_key)
        leaves = jax.tree_util.tree_leaves(params)
        path = os.path.join(self.outdir, f"{params_key}.params.bin")
        with open(path, "wb") as f:
            for leaf in leaves:
                arr = np.asarray(leaf)
                assert arr.dtype == np.float32, "all params are f32"
                f.write(arr.astype("<f4").tobytes())
        print(f"  params {params_key}: {count_params(params)} parameters")

    def export(
        self,
        name: str,
        kind: str,
        params_key: str,
        params,
        flat_fn,
        extra_args: list[tuple[str, str, jax.ShapeDtypeStruct]],
        output_roles,
        meta: dict,
        n_param_copies: int = 1,
    ) -> None:
        """Lower flat_fn(*(param leaves × n_param_copies), *extras) and write
        all three files. `output_roles` is a list of role strings matching
        flat_fn's flat outputs; param-shaped output blocks are expanded."""
        if self._skip(name):
            return
        leaves = jax.tree_util.tree_leaves(params)
        param_specs = [_spec_of(l) for l in leaves]
        arg_entries = []
        roles_in = ["param", "opt_m", "opt_v"]
        for i in range(n_param_copies):
            arg_entries += _param_entries(params, roles_in[i])
        for aname, role, spec in extra_args:
            arg_entries.append(
                {
                    "name": f"{role}:{aname}",
                    "role": role,
                    "shape": list(spec.shape),
                    "dtype": _dtype_str(spec.dtype),
                }
            )
        all_specs = param_specs * n_param_copies + [s for _, _, s in extra_args]

        lowered = jax.jit(flat_fn).lower(*all_specs)
        hlo = to_hlo_text(lowered)
        with open(os.path.join(self.outdir, f"{name}.hlo.txt"), "w") as f:
            f.write(hlo)

        out_entries = []
        out_specs = jax.eval_shape(flat_fn, *all_specs)
        flat_roles = []
        for role in output_roles:
            if role in ("param", "opt_m", "opt_v"):
                flat_roles += [role] * len(leaves)
            else:
                flat_roles.append(role)
        assert len(flat_roles) == len(out_specs), (
            f"{name}: {len(flat_roles)} roles vs {len(out_specs)} outputs"
        )
        for role, spec in zip(flat_roles, out_specs):
            out_entries.append(
                {
                    "role": role,
                    "shape": list(spec.shape),
                    "dtype": _dtype_str(spec.dtype),
                }
            )
        manifest = {
            "name": name,
            "kind": kind,
            "hlo": f"{name}.hlo.txt",
            "params_key": params_key,
            "params_bin": f"{params_key}.params.bin",
            "args": arg_entries,
            "outputs": out_entries,
            "meta": meta,
        }
        with open(os.path.join(self.outdir, f"{name}.manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        self.write_params(params_key, params)
        print(f"  wrote {name} ({len(hlo)} chars, {len(arg_entries)} args)")

    # -- generic builders ---------------------------------------------------

    def train_artifact(self, name, params_key, params, loss_fn, inputs, lr, meta):
        """inputs: list of (name, ShapeDtypeStruct)."""
        if self._skip(name):
            return
        _, tree = jax.tree_util.tree_flatten(params)
        n = len(jax.tree_util.tree_leaves(params))
        step_fn = make_train_step(loss_fn, lr=lr)

        def flat_fn(*args):
            p = jax.tree_util.tree_unflatten(tree, args[:n])
            m = jax.tree_util.tree_unflatten(tree, args[n : 2 * n])
            v = jax.tree_util.tree_unflatten(tree, args[2 * n : 3 * n])
            step = args[3 * n]
            batch = args[3 * n + 1 :]
            p2, m2, v2, s2, loss = step_fn(p, m, v, step, *batch)
            return (
                tuple(jax.tree_util.tree_leaves(p2))
                + tuple(jax.tree_util.tree_leaves(m2))
                + tuple(jax.tree_util.tree_leaves(v2))
                + (s2, loss)
            )

        extra = [("opt_step", "opt_step", jax.ShapeDtypeStruct((), jnp.float32))]
        extra += [(nm, "input", sp) for nm, sp in inputs]
        self.export(
            name,
            "train",
            params_key,
            params,
            flat_fn,
            extra,
            ["param", "opt_m", "opt_v", "opt_step", "aux"],
            dict(meta, lr=lr),
            n_param_copies=3,
        )

    def fwd_artifact(self, name, kind, params_key, params, fn, inputs, n_outputs, meta):
        if self._skip(name):
            return
        _, tree = jax.tree_util.tree_flatten(params)
        n = len(jax.tree_util.tree_leaves(params))

        def flat_fn(*args):
            p = jax.tree_util.tree_unflatten(tree, args[:n])
            out = fn(p, *args[n:])
            return out if isinstance(out, tuple) else (out,)

        extra = [(nm, role, sp) for nm, role, sp in inputs]
        self.export(
            name, kind, params_key, params, flat_fn, extra,
            ["aux"] * n_outputs, meta,
        )

    def step_artifact(self, name, params_key, params, fn, states, inputs, meta):
        """Streaming step: fn(params, *states, *inputs) ->
        (*states', y). `states` is a list of (name, ShapeDtypeStruct) whose
        outputs are fed back in order by the rust session manager."""
        if self._skip(name):
            return
        _, tree = jax.tree_util.tree_flatten(params)
        n = len(jax.tree_util.tree_leaves(params))

        def flat_fn(*args):
            p = jax.tree_util.tree_unflatten(tree, args[:n])
            return fn(p, *args[n:])

        extra = [(nm, "state", sp) for nm, sp in states]
        extra += [(nm, "input", sp) for nm, sp in inputs]
        roles = ["state"] * len(states) + ["aux"]
        self.export(name, "step", params_key, params, flat_fn, extra, roles, meta)


# ---------------------------------------------------------------------------
# artifact definitions


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def export_stream(ex: Exporter) -> None:
    c, n, b = STREAM["channels"], STREAM["seq"], STREAM["batch"]
    for kind in ("aaren", "tf"):
        cfg = ModelCfg(kind=kind, **STREAM_CFG)
        params = model.init_stream(jax.random.PRNGKey(0), cfg, c)
        key = f"stream_{kind}"
        meta = dict(STREAM, **STREAM_CFG, kind=kind)
        ex.train_artifact(
            f"stream_{kind}_train", key, params,
            lambda p, x, cfg=cfg: model.stream_loss(p, cfg, x),
            [("x", f32(b, n, c))], STREAM["lr"], meta,
        )
        ex.fwd_artifact(
            f"stream_{kind}_fwd", "fwd", key, params,
            lambda p, x, cfg=cfg: model.stream_forward(p, cfg, x),
            [("x", "input", f32(1, n, c))], 1, meta,
        )
        if kind == "aaren":
            L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
            ex.step_artifact(
                f"stream_{kind}_step", key, params,
                lambda p, a, cc, m, t, x, cfg=cfg: infer.stream_aaren_step(
                    p, cfg, a, cc, m, t, x
                ),
                [("a", f32(L, H, dh)), ("c", f32(L, H)), ("m", f32(L, H))],
                [("t", i32()), ("x", f32(c))],
                meta,
            )
        else:
            for ctx in FIG5_BUCKETS:
                L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
                ex.step_artifact(
                    f"stream_tf_step_c{ctx}", key, params,
                    lambda p, kc, vc, t, x, cfg=cfg, ctx=ctx: infer.stream_tf_step(
                        p, cfg, kc, vc, t, x, ctx
                    ),
                    [("k_cache", f32(L, H, ctx, dh)), ("v_cache", f32(L, H, ctx, dh))],
                    [("t", i32()), ("x", f32(c))],
                    dict(meta, ctx=ctx),
                )


def export_tsf(ex: Exporter) -> None:
    c, lb, b = TSF["channels"], TSF["lookback"], TSF["batch"]
    for kind in ("aaren", "tf"):
        cfg = ModelCfg(kind=kind, **SMALL_CFG)
        for T in TSF_HORIZONS:
            params = model.init_tsf(jax.random.PRNGKey(1), cfg, c, T)
            key = f"tsf_{kind}_T{T}"
            meta = dict(TSF, **SMALL_CFG, kind=kind, horizon=T)
            ex.train_artifact(
                f"tsf_{kind}_train_T{T}", key, params,
                lambda p, x, y, cfg=cfg, T=T: model.tsf_loss(p, cfg, T, x, y),
                [("x", f32(b, lb, c)), ("y", f32(b, T, c))], TSF["lr"], meta,
            )
            ex.fwd_artifact(
                f"tsf_{kind}_eval_T{T}", "eval", key, params,
                lambda p, x, y, cfg=cfg, T=T: model.tsf_eval(p, cfg, T, x, y),
                [("x", "input", f32(b, lb, c)), ("y", "input", f32(b, T, c))],
                2, meta,
            )


def export_tsc(ex: Exporter) -> None:
    c, n, ncls, b = TSC["channels"], TSC["seq"], TSC["classes"], TSC["batch"]
    for kind in ("aaren", "tf"):
        cfg = ModelCfg(kind=kind, **SMALL_CFG)
        params = model.init_tsc(jax.random.PRNGKey(2), cfg, c, ncls)
        key = f"tsc_{kind}"
        meta = dict(TSC, **SMALL_CFG, kind=kind)
        ex.train_artifact(
            f"tsc_{kind}_train", key, params,
            lambda p, x, lab, cfg=cfg: model.tsc_loss(p, cfg, x, lab),
            [("x", f32(b, n, c)), ("labels", i32(b))], TSC["lr"], meta,
        )
        ex.fwd_artifact(
            f"tsc_{kind}_eval", "eval", key, params,
            lambda p, x, lab, cfg=cfg: model.tsc_eval(p, cfg, x, lab),
            [("x", "input", f32(b, n, c)), ("labels", "input", i32(b))], 2, meta,
        )


def export_ef(ex: Exporter) -> None:
    n, marks, mix, b = EF["seq"], EF["marks"], EF["mix"], EF["batch"]
    for kind in ("aaren", "tf"):
        cfg = ModelCfg(kind=kind, **SMALL_CFG)
        params = model.init_ef(jax.random.PRNGKey(3), cfg, marks, mix)
        key = f"ef_{kind}"
        meta = dict(EF, **SMALL_CFG, kind=kind)
        ex.train_artifact(
            f"ef_{kind}_train", key, params,
            lambda p, t, mk, cfg=cfg: model.ef_loss(p, cfg, mix, t, mk),
            [("times", f32(b, n)), ("marks", i32(b, n))], EF["lr"], meta,
        )
        ex.fwd_artifact(
            f"ef_{kind}_eval", "eval", key, params,
            lambda p, t, mk, cfg=cfg: model.ef_eval(p, cfg, mix, t, mk),
            [("times", "input", f32(b, n)), ("marks", "input", i32(b, n))], 4, meta,
        )


def export_rl(ex: Exporter) -> None:
    t, s, a, b = RL["ctx"], RL["state_dim"], RL["act_dim"], RL["batch"]
    for kind in ("aaren", "tf"):
        cfg = ModelCfg(kind=kind, **RL_CFG)
        params = model.init_rl(jax.random.PRNGKey(4), cfg, s, a, RL["max_t"])
        key = f"rl_{kind}"
        meta = dict(RL, **RL_CFG, kind=kind)
        batch_specs = [
            ("rtg", f32(b, t, 1)), ("states", f32(b, t, s)),
            ("actions", f32(b, t, a)), ("timesteps", i32(b, t)),
            ("mask", f32(b, t)),
        ]
        ex.train_artifact(
            f"rl_{kind}_train", key, params,
            lambda p, *bt, cfg=cfg: model.rl_loss(p, cfg, *bt),
            batch_specs, RL["lr"], meta,
        )
        ex.fwd_artifact(
            f"rl_{kind}_eval", "eval", key, params,
            lambda p, *bt, cfg=cfg: model.rl_eval(p, cfg, *bt),
            [(nm, "input", sp) for nm, sp in batch_specs], 2, meta,
        )
        # online rollout: batch=1, right-aligned context
        act_specs = [
            ("rtg", "input", f32(1, t, 1)), ("states", "input", f32(1, t, s)),
            ("actions", "input", f32(1, t, a)), ("timesteps", "input", i32(1, t)),
            ("mask", "input", f32(1, t)),
        ]
        ex.fwd_artifact(
            f"rl_{kind}_act", "fwd", key, params,
            lambda p, *bt, cfg=cfg: model.rl_act(p, cfg, *bt),
            act_specs, 1, meta,
        )


def export_paramcount(ex: Exporter) -> None:
    """Paper-scale models for the §4.5 parameter-count comparison.
    Manifest-only (no HLO): we only need the counts."""
    counts = {}
    for kind in ("aaren", "tf"):
        cfg = ModelCfg(kind=kind, **PARAMCOUNT_CFG)
        params = model.init_stream(jax.random.PRNGKey(5), cfg, STREAM["channels"])
        counts[kind] = count_params(params)
    path = os.path.join(ex.outdir, "paramcount.json")
    with open(path, "w") as f:
        json.dump(dict(counts, **PARAMCOUNT_CFG), f, indent=1)
    print(f"  paramcount: tf={counts['tf']} aaren={counts['aaren']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--stamp", default=None, help="touch this file on success")
    args = ap.parse_args()
    ex = Exporter(os.path.abspath(args.outdir), args.only)
    for group, fn in [
        ("stream", export_stream),
        ("tsf", export_tsf),
        ("tsc", export_tsc),
        ("ef", export_ef),
        ("rl", export_rl),
    ]:
        print(f"[aot] exporting {group} artifacts")
        fn(ex)
    export_paramcount(ex)
    if args.stamp:
        with open(args.stamp, "w") as f:
            f.write("ok\n")
    print("[aot] done")


if __name__ == "__main__":
    main()
