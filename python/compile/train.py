# In-graph Adam: the entire optimisation step — forward, backward, global
# gradient-norm clipping, and the Adam update — is one HLO module. The rust
# coordinator holds the (params, m, v, step) buffers and simply feeds each
# call's outputs back into the next call's inputs; no optimiser logic ever
# runs outside XLA.
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_train_step(
    loss_fn,
    lr: float = 1e-3,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    clip_norm: float = 1.0,
):
    """loss_fn(params, *batch) -> scalar. Returns
    train_step(params, m, v, step, *batch) -> (params', m', v', step', loss).
    `step` is a float32 scalar (simplifies marshalling; exactly counts
    steps for the bias correction)."""

    def train_step(params, m, v, step, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)

        gnorm = jnp.sqrt(
            sum(jnp.sum(g**2) for g in jax.tree_util.tree_leaves(grads))
        )
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        step_new = step + 1.0
        bc1 = 1.0 - beta1**step_new
        bc2 = 1.0 - beta2**step_new

        def upd(p, g, m_i, v_i):
            m_n = beta1 * m_i + (1.0 - beta1) * g
            v_n = beta2 * v_i + (1.0 - beta2) * g * g
            p_n = p - lr * (m_n / bc1) / (jnp.sqrt(v_n / bc2) + eps)
            return p_n, m_n, v_n

        out = jax.tree_util.tree_map(upd, params, grads, m, v)
        params_new = jax.tree_util.tree_map(
            lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
        )
        m_new = jax.tree_util.tree_map(
            lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)
        )
        v_new = jax.tree_util.tree_map(
            lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple)
        )
        return params_new, m_new, v_new, step_new, loss

    return train_step
