# L2: the paper's models per evaluation domain, each in two flavours
# (kind="aaren" | kind="tf") sharing every hyperparameter — the paper's
# controlled comparison (§4, Appendix E).
#
#   stream — generic next-value sequence model: quickstart, serving demo,
#            Figure-5 analysis, and the streaming==parallel contract.
#   tsf    — time-series forecasting with instance (non-stationary) input
#            normalisation, following Liu et al. (2022) (§4.3, Tables 3/5).
#   tsc    — time-series classification: mean-pool + linear head (§4.4,
#            Table 4).
#   ef     — Transformer Hawkes Process-style event forecasting with a
#            log-normal mixture head (Zuo et al. 2020; Bae et al. 2023)
#            (§4.2, Table 2).
#   rl     — Decision Transformer (Chen et al., 2021): return-conditioned
#            action prediction over (rtg, state, action) token triples
#            (§4.1, Table 1).
#
# Every model exposes  init_*(key, ...) -> params,
#                      *_loss(params, batch...) -> scalar,
# and a forward/eval function the AOT exporter lowers for the rust side.
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (
    ModelCfg,
    backbone_apply,
    init_backbone,
    init_linear,
    linear,
    sinusoidal_positions,
    temporal_encoding,
)

# ---------------------------------------------------------------------------
# stream: generic next-step prediction over continuous multichannel tokens


def init_stream(key, cfg: ModelCfg, n_channels: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "embed": init_linear(ks[0], n_channels, cfg.d_model),
        "backbone": init_backbone(ks[1], cfg),
        "head": init_linear(ks[2], cfg.d_model, n_channels),
    }


def stream_forward(params: dict, cfg: ModelCfg, x: jax.Array) -> jax.Array:
    """x: (B, N, C) -> per-token next-value predictions (B, N, C)."""
    b, n, _ = x.shape
    h = linear(params["embed"], x) + sinusoidal_positions(n, cfg.d_model)[None]
    mask = jnp.ones((b, n), jnp.float32)
    h = backbone_apply(params["backbone"], cfg, h, mask)
    return linear(params["head"], h)


def stream_loss(params: dict, cfg: ModelCfg, x: jax.Array) -> jax.Array:
    """Next-step MSE: prediction at t is scored against x_{t+1}."""
    pred = stream_forward(params, cfg, x)
    return jnp.mean((pred[:, :-1] - x[:, 1:]) ** 2)


# ---------------------------------------------------------------------------
# tsf: forecasting with instance normalisation (Liu et al., 2022)


def init_tsf(key, cfg: ModelCfg, n_channels: int, horizon: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "embed": init_linear(ks[0], n_channels, cfg.d_model),
        "backbone": init_backbone(ks[1], cfg),
        "head": init_linear(ks[2], cfg.d_model, horizon * n_channels),
    }


def _instance_norm(x: jax.Array, eps: float = 1e-5):
    """Per-instance, per-channel normalisation over the time axis."""
    mu = jnp.mean(x, axis=1, keepdims=True)
    sigma = jnp.sqrt(jnp.var(x, axis=1, keepdims=True) + eps)
    return (x - mu) / sigma, mu, sigma


def tsf_forward(params: dict, cfg: ModelCfg, horizon: int, x: jax.Array) -> jax.Array:
    """x: (B, L, C) history -> (B, T, C) forecast (de-normalised)."""
    b, n, c = x.shape
    xn, mu, sigma = _instance_norm(x)
    h = linear(params["embed"], xn) + sinusoidal_positions(n, cfg.d_model)[None]
    mask = jnp.ones((b, n), jnp.float32)
    h = backbone_apply(params["backbone"], cfg, h, mask)
    yn = linear(params["head"], h[:, -1]).reshape(b, horizon, c)
    return yn * sigma + mu


def tsf_loss(
    params: dict, cfg: ModelCfg, horizon: int, x: jax.Array, y: jax.Array
) -> jax.Array:
    """MSE on the *normalised* scale (standard for instance-norm models)."""
    _, mu, sigma = _instance_norm(x)
    pred = tsf_forward(params, cfg, horizon, x)
    return jnp.mean(((pred - mu) / sigma - (y - mu) / sigma) ** 2)


def tsf_eval(
    params: dict, cfg: ModelCfg, horizon: int, x: jax.Array, y: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Returns (sum squared error, sum abs error) on the original scale;
    the rust harness divides by element count to report MSE/MAE as the
    paper does (datasets are pre-standardised by the generators)."""
    pred = tsf_forward(params, cfg, horizon, x)
    err = pred - y
    return jnp.sum(err**2), jnp.sum(jnp.abs(err))


# ---------------------------------------------------------------------------
# tsc: sequence classification (mean pooling, Wu et al. 2023 style)


def init_tsc(key, cfg: ModelCfg, n_channels: int, n_classes: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "embed": init_linear(ks[0], n_channels, cfg.d_model),
        "backbone": init_backbone(ks[1], cfg),
        "head": init_linear(ks[2], cfg.d_model, n_classes),
    }


def tsc_logits(params: dict, cfg: ModelCfg, x: jax.Array) -> jax.Array:
    b, n, _ = x.shape
    h = linear(params["embed"], x) + sinusoidal_positions(n, cfg.d_model)[None]
    mask = jnp.ones((b, n), jnp.float32)
    h = backbone_apply(params["backbone"], cfg, h, mask)
    return linear(params["head"], jnp.mean(h, axis=1))


def tsc_loss(params: dict, cfg: ModelCfg, x: jax.Array, labels: jax.Array) -> jax.Array:
    logits = tsc_logits(params, cfg, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def tsc_eval(
    params: dict, cfg: ModelCfg, x: jax.Array, labels: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Returns (correct count, summed NLL)."""
    logits = tsc_logits(params, cfg, x)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.sum(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    return correct, nll


# ---------------------------------------------------------------------------
# ef: Transformer Hawkes Process with a log-normal mixture head


LOG_SIG_MIN, LOG_SIG_MAX = -3.0, 1.5


def init_ef(key, cfg: ModelCfg, n_marks: int, n_mix: int) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "mark_embed": jax.random.normal(ks[0], (n_marks, cfg.d_model)) * 0.02,
        "backbone": init_backbone(ks[1], cfg),
        # per-event distribution head: mixture weights, means, log-sigmas
        "time_head": init_linear(ks[2], cfg.d_model, 3 * n_mix),
        "mark_head": init_linear(ks[3], cfg.d_model, n_marks),
    }


def _ef_hidden(params: dict, cfg: ModelCfg, times: jax.Array, marks: jax.Array):
    """times: (B, L) absolute event times; marks: (B, L) int32 -> (B, L, d)."""
    b, n = times.shape
    h = params["mark_embed"][marks] + temporal_encoding(times, cfg.d_model)
    mask = jnp.ones((b, n), jnp.float32)
    return backbone_apply(params["backbone"], cfg, h, mask)


def _lognormal_mixture_nll(head_out: jax.Array, dt: jax.Array, n_mix: int):
    """NLL of inter-event gaps dt>0 under a log-normal mixture.

    head_out: (..., 3K) -> weights/mu/log-sigma; dt: (...,).
    Returns (nll, expected_dt) where expected_dt is the mixture mean used
    for the paper's RMSE metric.
    """
    w_logit, mu, log_sig = jnp.split(head_out, 3, axis=-1)
    log_w = jax.nn.log_softmax(w_logit, axis=-1)
    log_sig = jnp.clip(log_sig, LOG_SIG_MIN, LOG_SIG_MAX)
    sig = jnp.exp(log_sig)
    logdt = jnp.log(jnp.maximum(dt, 1e-8))[..., None]
    # log N(log dt; mu, sig) - log dt   (log-normal density)
    comp = (
        -0.5 * ((logdt - mu) / sig) ** 2
        - log_sig
        - 0.5 * jnp.log(2.0 * jnp.pi)
        - logdt
    )
    nll = -jax.nn.logsumexp(log_w + comp, axis=-1)
    # Point prediction for the RMSE metric: mixture of component *medians*
    # exp(mu_k). The mixture mean exp(mu + sigma^2/2) is heavy-tailed and
    # explodes for untrained/high-variance components; the median is the
    # standard robust reporting choice for log-normal TPP heads.
    expected = jnp.sum(jnp.exp(log_w) * jnp.exp(mu), axis=-1)
    return nll, expected


def ef_loss(
    params: dict, cfg: ModelCfg, n_mix: int, times: jax.Array, marks: jax.Array
) -> jax.Array:
    """Mean NLL of (next gap, next mark) over positions 1..L-1."""
    h = _ef_hidden(params, cfg, times, marks)[:, :-1]  # h_t predicts event t+1
    dt = times[:, 1:] - times[:, :-1]
    time_nll, _ = _lognormal_mixture_nll(linear(params["time_head"], h), dt, n_mix)
    logits = jax.nn.log_softmax(linear(params["mark_head"], h), axis=-1)
    mark_nll = -jnp.take_along_axis(logits, marks[:, 1:, None], axis=-1)[..., 0]
    return jnp.mean(time_nll + mark_nll)


def ef_eval(
    params: dict, cfg: ModelCfg, n_mix: int, times: jax.Array, marks: jax.Array
):
    """Returns (nll_sum, sq_err_sum, correct_marks, n_events) — the paper's
    Table-2 metrics (NLL / RMSE / Acc) before aggregation."""
    h = _ef_hidden(params, cfg, times, marks)[:, :-1]
    dt = times[:, 1:] - times[:, :-1]
    time_nll, dt_pred = _lognormal_mixture_nll(linear(params["time_head"], h), dt, n_mix)
    logits = linear(params["mark_head"], h)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mark_nll = -jnp.take_along_axis(logp, marks[:, 1:, None], axis=-1)[..., 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == marks[:, 1:]).astype(jnp.float32))
    n = jnp.asarray(dt.size, jnp.float32)
    return (
        jnp.sum(time_nll + mark_nll),
        jnp.sum((dt_pred - dt) ** 2),
        correct,
        n,
    )


# ---------------------------------------------------------------------------
# rl: Decision Transformer (return-to-go conditioning)


def init_rl(
    key, cfg: ModelCfg, state_dim: int, act_dim: int, max_timesteps: int
) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "embed_rtg": init_linear(ks[0], 1, cfg.d_model),
        "embed_state": init_linear(ks[1], state_dim, cfg.d_model),
        "embed_action": init_linear(ks[2], act_dim, cfg.d_model),
        "embed_t": jax.random.normal(ks[3], (max_timesteps, cfg.d_model)) * 0.02,
        "backbone": init_backbone(ks[4], cfg),
        "head": init_linear(jax.random.split(ks[4])[0], cfg.d_model, act_dim),
    }


def rl_forward(
    params: dict,
    cfg: ModelCfg,
    rtg: jax.Array,  # (B, T, 1)
    states: jax.Array,  # (B, T, S)
    actions: jax.Array,  # (B, T, A)
    timesteps: jax.Array,  # (B, T) int32
    mask: jax.Array,  # (B, T) in {0,1}
) -> jax.Array:
    """Predict actions from state-token positions. Returns (B, T, A)."""
    b, t, _ = states.shape
    te = params["embed_t"][timesteps]  # (B, T, d)
    e_r = linear(params["embed_rtg"], rtg) + te
    e_s = linear(params["embed_state"], states) + te
    e_a = linear(params["embed_action"], actions) + te
    # interleave (r_1, s_1, a_1, r_2, s_2, a_2, ...) -> (B, 3T, d)
    tokens = jnp.stack([e_r, e_s, e_a], axis=2).reshape(b, 3 * t, cfg.d_model)
    mask3 = jnp.repeat(mask, 3, axis=-1)
    h = backbone_apply(params["backbone"], cfg, tokens, mask3)
    h_state = h.reshape(b, t, 3, cfg.d_model)[:, :, 1]  # hidden at state tokens
    return jnp.tanh(linear(params["head"], h_state))


def rl_loss(params, cfg, rtg, states, actions, timesteps, mask) -> jax.Array:
    pred = rl_forward(params, cfg, rtg, states, actions, timesteps, mask)
    se = jnp.sum((pred - actions) ** 2, axis=-1) * mask
    return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)


def rl_eval(params, cfg, rtg, states, actions, timesteps, mask):
    """Returns (masked squared-error sum, mask sum) for held-out action MSE."""
    pred = rl_forward(params, cfg, rtg, states, actions, timesteps, mask)
    se = jnp.sum((pred - actions) ** 2, axis=-1) * mask
    return jnp.sum(se), jnp.sum(mask)


def rl_act(params, cfg, rtg, states, actions, timesteps, mask) -> jax.Array:
    """Action for the *last* context slot — the online rollout step. The
    rust coordinator right-aligns the live episode into the fixed context
    window and sets mask accordingly. Returns (B, A)."""
    pred = rl_forward(params, cfg, rtg, states, actions, timesteps, mask)
    return pred[:, -1]
