# L2 building blocks: layer norm, MLP, positional encodings, and the two
# attention blocks the paper compares — the Aaren block (learned query +
# prefix-scan attention, §3.3) and the causal Transformer block (Vaswani
# et al., 2017). Both share every hyperparameter; the only differences are
# (a) where the query comes from and (b) which L1 kernel runs — exactly
# the paper's controlled comparison.
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.causal_attention import causal_attention
from .kernels.scan_attention import scan_attention


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Shared architecture hyperparameters (paper Appendix E)."""

    kind: str  # "aaren" | "tf"
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_mlp: int = 128

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# primitives


def init_linear(key, d_in: int, d_out: int) -> dict:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def linear(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def init_layer_norm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    return linear(p["fc2"], jax.nn.gelu(linear(p["fc1"], x)))


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Standard fixed sinusoidal position table, (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def sinusoidal_at(t: jax.Array, d: int) -> jax.Array:
    """Positional row for a single (traced) integer position t — O(1),
    used by the streaming infer step."""
    tf = t.astype(jnp.float32)
    i = jnp.arange(d // 2, dtype=jnp.float32)
    angle = tf / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def temporal_encoding(times: jax.Array, d: int) -> jax.Array:
    """THP-style encoding of continuous event times (Zuo et al., 2020).

    times: (..., L) absolute event times -> (..., L, d).
    """
    i = jnp.arange(d // 2, dtype=jnp.float32)
    angle = times[..., None] / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# attention blocks


def init_block(key, cfg: ModelCfg) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {
        "ln1": init_layer_norm(d),
        "wk": init_linear(ks[0], d, d),
        "wv": init_linear(ks[1], d, d),
        "wo": init_linear(ks[2], d, d),
        "ln2": init_layer_norm(d),
        "mlp": {
            "fc1": init_linear(ks[3], d, cfg.d_mlp),
            "fc2": init_linear(ks[4], cfg.d_mlp, d),
        },
    }
    # Both variants own a query projection Wq; Aaren additionally learns
    # the query *token* q (paper §3.3: "Aaren's query token q is learned
    # during training via backpropagation"), which is projected through Wq
    # like any input token. This gives Aaren exactly +d_model parameters
    # per block — the paper's ~0.016% overhead (§4.5).
    p["wq"] = init_linear(ks[5], d, d)
    if cfg.kind == "aaren":
        p["q"] = jax.random.normal(ks[6], (d,)) * 0.02
    elif cfg.kind != "tf":
        raise ValueError(f"unknown block kind {cfg.kind!r}")
    return p


def _split_heads(x: jax.Array, h: int) -> jax.Array:
    """(B, N, d) -> (B*h, N, d/h)."""
    b, n, d = x.shape
    return x.reshape(b, n, h, d // h).transpose(0, 2, 1, 3).reshape(b * h, n, d // h)


def _merge_heads(x: jax.Array, b: int) -> jax.Array:
    """(B*h, N, dh) -> (B, N, d)."""
    bh, n, dh = x.shape
    h = bh // b
    return x.reshape(b, h, n, dh).transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def block_apply(p: dict, cfg: ModelCfg, x: jax.Array, mask: jax.Array) -> jax.Array:
    """Pre-norm residual block. x: (B, N, d); mask: (B, N) in {0,1}.

    Both variants map N inputs to N outputs where output i aggregates
    inputs 1..i (the shared interface of §3.3).
    """
    b, n, _ = x.shape
    h_in = layer_norm(p["ln1"], x)
    k = _split_heads(linear(p["wk"], h_in), cfg.n_heads)
    v = _split_heads(linear(p["wv"], h_in), cfg.n_heads)
    mask_bh = jnp.repeat(mask, cfg.n_heads, axis=0)  # (B*h, N)

    if cfg.kind == "aaren":
        # project the learned query token, split into heads, tile per batch
        q_heads = linear(p["wq"], p["q"]).reshape(cfg.n_heads, cfg.d_head)
        q = jnp.tile(q_heads, (b, 1))  # (B*h, dh): input-independent
        o = scan_attention(q, k, v, mask_bh)
    else:
        q = _split_heads(linear(p["wq"], h_in), cfg.n_heads)
        o = causal_attention(q, k, v, mask_bh)

    x = x + linear(p["wo"], _merge_heads(o, b))
    x = x + mlp_apply(p["mlp"], layer_norm(p["ln2"], x))
    return x


def init_backbone(key, cfg: ModelCfg) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 1)
    return {
        "blocks": [init_block(ks[i], cfg) for i in range(cfg.n_layers)],
        "ln_f": init_layer_norm(cfg.d_model),
    }


def backbone_apply(p: dict, cfg: ModelCfg, x: jax.Array, mask: jax.Array) -> jax.Array:
    """Stacked blocks + final norm (Figure 4's stacking)."""
    for blk in p["blocks"]:
        x = block_apply(blk, cfg, x, mask)
    return layer_norm(p["ln_f"], x)


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
